"""Python mirror of the event-driven inverted-index TM inference tier.

Mirrors ``rust/src/tm/index.rs`` algorithm-for-algorithm so the counter
sweep can be validated (hand-worked oracles, cross-language golden
vectors, randomized differential tests against a direct evaluator) on
CI images that carry no Rust toolchain — the same arrangement as
``hashring.py`` for the shard router. Any change to the Rust counter
algorithm must be replayed here and in both golden-vector test suites.

Algorithm (arXiv 2004.03188, clause indexing)
---------------------------------------------
Literals are interleaved: ``literal[2i] = x_i``, ``literal[2i+1] =
not x_i``, so exactly F of the 2F literals are *set* per sample. Each
clause keeps a counter of unsatisfied included literals, initialised to
its included-literal count. Evaluating a sample walks only the set
literals and decrements the counter of every clause whose include mask
names that literal (via the literal -> clauses inverted index); a
clause fires exactly when its counter reaches zero. A second walk over
the same postings restores the counters, so the scratch state is reused
across a batch in O(touched) instead of O(clauses).

Conventions pinned to the scalar reference:

* An empty (all-exclude) clause appears in no literal's clause list;
  its counter starts at 0 but is never decremented, so it never fires.
* A clause including both ``x_i`` and ``not x_i`` never fires (only one
  of the pair is ever set).
"""


class InvertedIndex:
    """Literal -> clause inverted index with unsatisfied-literal counters.

    ``masks`` is a list of clauses, each a list of 2F booleans
    (include mask over the interleaved literals).
    """

    def __init__(self, features, masks):
        self.features = features
        self.clause_lists = [[] for _ in range(2 * features)]
        self.required = []
        for c, mask in enumerate(masks):
            if len(mask) != 2 * features:
                raise ValueError("mask width != 2F")
            self.required.append(sum(1 for b in mask if b))
            for lit, inc in enumerate(mask):
                if inc:
                    self.clause_lists[lit].append(c)
        # Reusable scratch: counters in the reset state, restored by
        # every sweep.
        self._counts = list(self.required)

    def num_clauses(self):
        return len(self.required)

    def postings(self):
        return sum(self.required)

    def live_clauses(self):
        """Clauses that include at least one literal (dead all-exclude
        clauses never fire and carry no postings)."""
        return sum(1 for r in self.required if r > 0)

    def density(self):
        """Included-literal density over **live** clauses only, mirroring
        ``InvertedIndex::density`` in index.rs: dead clauses contribute
        no postings, so counting them in the denominator dilutes the
        density and skews the three-way ``auto-*`` crossover."""
        total = self.live_clauses() * 2 * self.features
        return self.postings() / total if total else 0.0

    def sweep(self, sample):
        """Fired clause ids for one sample, in event order."""
        if len(sample) != self.features:
            raise ValueError("sample width != F")
        counts = self._counts
        fired = []
        for i, f in enumerate(sample):
            lit = 2 * i + (0 if f else 1)
            for c in self.clause_lists[lit]:
                counts[c] -= 1
                if counts[c] == 0:
                    fired.append(c)
        # Event-driven undo: restore only the touched counters.
        for i, f in enumerate(sample):
            lit = 2 * i + (0 if f else 1)
            for c in self.clause_lists[lit]:
                counts[c] += 1
        return fired


class IndexedMulticlass:
    """Indexed multi-class TM: clause id = class * C + j, polarity
    alternates +/- with j (Eq. 1)."""

    def __init__(self, clauses):
        # clauses: [K][C][2F] include masks.
        self.classes = len(clauses)
        self.clauses_per_class = len(clauses[0])
        features = len(clauses[0][0]) // 2
        flat = [mask for cls in clauses for mask in cls]
        self.index = InvertedIndex(features, flat)

    def class_sums(self, sample):
        sums = [0] * self.classes
        c = self.clauses_per_class
        for cid in self.index.sweep(sample):
            k, j = divmod(cid, c)
            sums[k] += 1 if j % 2 == 0 else -1
        return sums


class IndexedCotm:
    """Indexed CoTM: shared clause pool + signed weights (Eq. 2)."""

    def __init__(self, clauses, weights):
        # clauses: [C][2F]; weights: [K][C].
        features = len(clauses[0]) // 2
        self.index = InvertedIndex(features, clauses)
        self.classes = len(weights)
        # Clause-major weight columns, like the Rust engine.
        self.weight_cols = [
            [weights[k][j] for k in range(self.classes)]
            for j in range(len(clauses))
        ]

    def class_sums(self, sample):
        sums = [0] * self.classes
        for cid in self.index.sweep(sample):
            for k, w in enumerate(self.weight_cols[cid]):
                sums[k] += w
        return sums


# ---------------------------------------------------------------------
# Direct (non-indexed) reference evaluator, used by the differential
# tests: the straightforward reading of Eq. 1/2, matching
# rust/src/tm/infer.rs.
# ---------------------------------------------------------------------

def make_literals(features):
    """Interleave: [x0, not x0, x1, not x1, ...]."""
    lits = []
    for f in features:
        lits.append(bool(f))
        lits.append(not f)
    return lits


def clause_output(mask, lits):
    """Empty clauses output 0 at inference; otherwise AND of included."""
    if not any(mask):
        return 0
    return int(all(lit for inc, lit in zip(mask, lits) if inc))


def ref_multiclass_class_sums(clauses, sample):
    lits = make_literals(sample)
    sums = []
    for cls in clauses:
        s = 0
        for j, mask in enumerate(cls):
            out = clause_output(mask, lits)
            s += out if j % 2 == 0 else -out
        sums.append(s)
    return sums


def ref_cotm_class_sums(clauses, weights, sample):
    lits = make_literals(sample)
    outs = [clause_output(mask, lits) for mask in clauses]
    return [sum(w * o for w, o in zip(row, outs)) for row in weights]
