"""Python mirror of the coordinator's consistent-hash ring.

Mirrors ``rust/src/coordinator/shard.rs`` bit-for-bit so the routing
algorithm can be validated (determinism, distribution, wrap-around,
cross-language golden vectors) on CI images that carry no Rust
toolchain. Any change to the Rust hashing/ring code must be replayed
here and in both golden-vector test suites.

Algorithm
---------
* ``hash_bytes`` = FNV-1a/64 over the byte stream, finished with the
  splitmix64 mixer (raw FNV-1a has poor avalanche on short
  little-endian integer inputs; the vnode points cluster without it).
* Keys: an explicit ``u64`` shard key hashes its 8 little-endian
  bytes; a boolean feature vector hashes one 0/1 byte per feature.
* Ring: each shard contributes ``DEFAULT_VNODES`` points at
  ``hash_bytes(shard_le8 + replica_le8)``; a key routes to the shard
  owning the first point at or after the key's hash, wrapping past the
  top of the ``u64`` space.
"""

import bisect

MASK64 = (1 << 64) - 1

#: Virtual nodes per shard — keep in sync with shard.rs.
DEFAULT_VNODES = 128


def fnv1a64(data):
    """FNV-1a 64-bit over an iterable of ints in [0, 255]."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK64
    return h


def mix64(z):
    """splitmix64 finalizer."""
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64


def hash_bytes(data):
    """The ring hash: FNV-1a/64 finished with the splitmix64 mixer."""
    return mix64(fnv1a64(data))


def hash_key(key):
    """Hash an explicit u64 shard key (its little-endian bytes)."""
    return hash_bytes((key & MASK64).to_bytes(8, "little"))


def hash_features(features):
    """Hash a boolean feature vector (one byte per feature, 0/1)."""
    return hash_bytes(bytes(1 if b else 0 for b in features))


def vnode_point(shard, replica):
    """Ring position of one virtual node."""
    return hash_bytes(
        shard.to_bytes(8, "little") + replica.to_bytes(8, "little")
    )


class HashRing:
    """Deterministic consistent-hash ring over ``shards`` shards."""

    def __init__(self, shards, vnodes=DEFAULT_VNODES):
        if shards < 1:
            raise ValueError("hash ring needs >= 1 shard")
        if vnodes < 1:
            raise ValueError("hash ring needs >= 1 vnode per shard")
        # (position, shard), sorted; ties break on shard id, matching
        # the Rust sort of (u64, u32) tuples.
        self.points = sorted(
            (vnode_point(s, r), s)
            for s in range(shards)
            for r in range(vnodes)
        )

    def shard_for_hash(self, h):
        """First vnode at or after ``h``, wrapping past the top."""
        i = bisect.bisect_left(self.points, (h, -1))
        return self.points[i % len(self.points)][1]

    def shard_for_key(self, key):
        return self.shard_for_hash(hash_key(key))

    def shard_for_features(self, features):
        return self.shard_for_hash(hash_features(features))

    def shards(self):
        """Number of distinct shards on the ring."""
        return max(s for _, s in self.points) + 1 if self.points else 0

    def walk_from_hash(self, h):
        """Every distinct shard in ring order starting at ``h``'s owner
        — the deterministic failover sequence the networked router
        (``rust/src/coordinator/net/client.rs``) tries when earlier
        shards are marked unhealthy. ``walk_from_hash(h)[0] ==
        shard_for_hash(h)`` always."""
        n = self.shards()
        out = []
        start = bisect.bisect_left(self.points, (h, -1))
        for k in range(len(self.points)):
            s = self.points[(start + k) % len(self.points)][1]
            if s not in out:
                out.append(s)
                if len(out) == n:
                    break
        return out
