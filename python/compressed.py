"""Python mirror of the compressed-clause (ETHEREAL) TM serving tier.

Mirrors ``rust/src/tm/compressed.rs`` algorithm-for-algorithm so the
include-list walk can be validated (hand-worked oracles, cross-language
golden vectors, randomized differential tests against a direct
evaluator) on CI images that carry no Rust toolchain — the same
arrangement as ``invindex.py`` for the counter sweep. Any change to the
Rust compressed algorithm must be replayed here and in both
golden-vector test suites.

Algorithm (arXiv 2502.05640, ETHEREAL)
--------------------------------------
Trained TMs are overwhelmingly excludes, so each clause is compressed
to its **sorted include-literal list** (CSR layout: one flat literal
array plus per-clause offsets). Evaluation walks only the include list
and **early-exits on the first unsatisfied literal**. An optional
literal-frequency reorder rewrites each clause's walk order so globally
hot literals cluster at the front (descending global frequency, ties by
ascending literal id) — a speed decision only: clause firing is an AND
over the same set, so outputs are invariant under any walk order.

Conventions pinned to the scalar reference:

* Literals interleave: ``literal[2i] = x_i``, ``literal[2i+1] = not
  x_i``.
* An empty (all-exclude) clause compresses to an empty list and never
  fires at inference.
* A clause including both ``x_i`` and ``not x_i`` always early-exits on
  one of the pair (only one is ever set).
"""

# Default thresholds of the three-way auto selection, mirrored from
# index.rs / compressed.rs.
PACKED_VS_INDEXED_DENSITY = 0.05
PACKED_VS_COMPRESSED_DENSITY = 0.2


def select_engine(density, indexed_threshold, compressed_threshold):
    """The three-way density-driven auto decision (pure and total over
    every threshold pair, including inverted or 0.0/1.0 edges):
    ``"indexed"`` first, then ``"compressed"``, else ``"packed"``."""
    if density <= indexed_threshold:
        return "indexed"
    if density <= compressed_threshold:
        return "compressed"
    return "packed"


class CompressedModel:
    """Per-clause sorted include-literal lists in CSR layout.

    ``masks`` is a list of clauses, each a list of 2F booleans (include
    mask over the interleaved literals); clause ids follow list order,
    so a multiclass caller's per-class grouping (id = class * C + j) is
    preserved as contiguous id ranges.
    """

    def __init__(self, features, masks):
        self.features = features
        self.literals = []
        self.offsets = [0]
        for mask in masks:
            if len(mask) != 2 * features:
                raise ValueError("mask width != 2F")
            for lit, inc in enumerate(mask):
                if inc:
                    self.literals.append(lit)
            self.offsets.append(len(self.literals))

    def num_clauses(self):
        return len(self.offsets) - 1

    def included(self, c):
        """Include list of clause ``c`` (in walk order)."""
        return self.literals[self.offsets[c]:self.offsets[c + 1]]

    def postings(self):
        return len(self.literals)

    def live_clauses(self):
        """Clauses with a non-empty include list (mirrors
        ``CompressedModel::live_clauses`` over the CSR offsets)."""
        return sum(
            1
            for c in range(self.num_clauses())
            if self.offsets[c + 1] > self.offsets[c]
        )

    def density(self):
        """Included-literal density over **live** clauses only (see
        ``invindex.InvertedIndex.density`` for the rationale)."""
        total = self.live_clauses() * 2 * self.features
        return self.postings() / total if total else 0.0

    def literal_frequencies(self):
        freq = [0] * (2 * self.features)
        for lit in self.literals:
            freq[lit] += 1
        return freq

    def reorder_by_frequency(self):
        """Hot literals first in each clause's walk (descending global
        frequency, ties by ascending literal id — the same deterministic
        key as the Rust engine)."""
        freq = self.literal_frequencies()
        for c in range(self.num_clauses()):
            lo, hi = self.offsets[c], self.offsets[c + 1]
            self.literals[lo:hi] = sorted(
                self.literals[lo:hi], key=lambda lit: (-freq[lit], lit)
            )

    def clause_fires(self, c, sample):
        """Early-exit walk of clause ``c``'s include list; empty clauses
        never fire at inference."""
        lits = self.included(c)
        if not lits:
            return False
        for lit in lits:
            value = sample[lit >> 1] if lit % 2 == 0 else not sample[lit >> 1]
            if not value:
                return False  # early exit — the whole point.
        return True

    def sweep(self, sample):
        """Fired clause ids for one sample, ascending."""
        if len(sample) != self.features:
            raise ValueError("sample width != F")
        return [
            c for c in range(self.num_clauses()) if self.clause_fires(c, sample)
        ]


class CompressedMulticlass:
    """Compressed multi-class TM: clause id = class * C + j, polarity
    alternates +/- with j (Eq. 1); frequency reorder applied at build,
    like the Rust engine."""

    def __init__(self, clauses):
        # clauses: [K][C][2F] include masks.
        self.classes = len(clauses)
        self.clauses_per_class = len(clauses[0])
        features = len(clauses[0][0]) // 2
        flat = [mask for cls in clauses for mask in cls]
        self.model = CompressedModel(features, flat)
        self.model.reorder_by_frequency()

    def class_sums(self, sample):
        sums = [0] * self.classes
        c = self.clauses_per_class
        for cid in self.model.sweep(sample):
            k, j = divmod(cid, c)
            sums[k] += 1 if j % 2 == 0 else -1
        return sums


class CompressedCotm:
    """Compressed CoTM: shared clause pool + signed weights (Eq. 2)."""

    def __init__(self, clauses, weights):
        # clauses: [C][2F]; weights: [K][C].
        features = len(clauses[0]) // 2
        self.model = CompressedModel(features, clauses)
        self.model.reorder_by_frequency()
        self.classes = len(weights)
        # Clause-major weight columns, like the Rust engine.
        self.weight_cols = [
            [weights[k][j] for k in range(self.classes)]
            for j in range(len(clauses))
        ]

    def class_sums(self, sample):
        sums = [0] * self.classes
        for cid in self.model.sweep(sample):
            for k, w in enumerate(self.weight_cols[cid]):
                sums[k] += w
        return sums
