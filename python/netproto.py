"""Bit-for-bit mirror of the serving wire protocol
(`rust/src/coordinator/net/frame.rs` + `net/msg.rs`).

The networked serving tier speaks a hand-rolled length-prefixed binary
protocol over TCP (std::net only — the Rust crate stays
dependency-free). Because the CI image carries no Rust toolchain, this
module re-implements the frame codec and every message's payload
layout byte-for-byte, and `python/tests/test_netproto.py` pins the
same golden byte-vectors the Rust unit tests assert — so the wire
format validates on toolchain-less images, exactly like the hash ring
in `python/hashring.py`.

Frame layout (all integers little-endian):

    offset  size  field
    0       4     magic  b"tmtd"
    4       1     protocol version (1)
    5       1     message type
    6       4     payload length (u32, <= MAX_PAYLOAD)
    10      n     payload

Message payloads (strings are u16 length + UTF-8 bytes):

    type  message        payload
    1     InferRequest   str backend, u32 nfeat, nfeat x u8 (0/1)
    2     InferResponse  str backend, u32 predicted, u32 nsums,
                         nsums x i32, f64 service_us
    3     Reject         str reason       (backpressure, not swallowed)
    4     Failed         str reason       (server-side error)
    5     Heartbeat      u64 nonce
    6     HeartbeatAck   u64 nonce
    7     StatsRequest   (empty)
    8     StatsReply     u64 submitted, completed, rejected, failed,
                         batches_flushed, batched_requests,
                         u32 nlat, nlat x f64, u32 nbatch, nbatch x f64
                         (the raw latency / batch-size sample rings —
                         shipped whole so the router aggregates exact
                         percentiles, not merged approximations)
    9     Drain          (empty)
    10    DrainAck       (empty)
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field

MAGIC = b"tmtd"
VERSION = 1
HEADER_LEN = 10
# 16 MiB: far above any real message (the stats rings cap at 100k f64
# samples ~ 800 KB each) while bounding a hostile length prefix.
MAX_PAYLOAD = 1 << 24

MSG_INFER_REQUEST = 1
MSG_INFER_RESPONSE = 2
MSG_REJECT = 3
MSG_FAILED = 4
MSG_HEARTBEAT = 5
MSG_HEARTBEAT_ACK = 6
MSG_STATS_REQUEST = 7
MSG_STATS_REPLY = 8
MSG_DRAIN = 9
MSG_DRAIN_ACK = 10


class NetProtoError(ValueError):
    """A malformed frame or payload (mirror of the Rust codec's
    coordinator errors — decoding must fail cleanly, never hang or
    crash)."""


# ---------------------------------------------------------------------------
# messages


@dataclass(frozen=True)
class InferRequest:
    backend: str
    features: tuple[bool, ...]


@dataclass(frozen=True)
class InferResponse:
    backend: str
    predicted: int
    class_sums: tuple[int, ...]
    service_us: float


@dataclass(frozen=True)
class Reject:
    reason: str


@dataclass(frozen=True)
class Failed:
    reason: str


@dataclass(frozen=True)
class Heartbeat:
    nonce: int


@dataclass(frozen=True)
class HeartbeatAck:
    nonce: int


@dataclass(frozen=True)
class StatsRequest:
    pass


@dataclass(frozen=True)
class StatsReply:
    submitted: int
    completed: int
    rejected: int
    failed: int
    batches_flushed: int
    batched_requests: int
    latency_samples: tuple[float, ...] = field(default=())
    batch_size_samples: tuple[float, ...] = field(default=())


@dataclass(frozen=True)
class Drain:
    pass


@dataclass(frozen=True)
class DrainAck:
    pass


# ---------------------------------------------------------------------------
# payload primitives


def _put_str(out: bytearray, s: str) -> None:
    raw = s.encode("utf-8")
    if len(raw) > 0xFFFF:
        raise NetProtoError("net: string too long for u16 length prefix")
    out += struct.pack("<H", len(raw))
    out += raw


class _Reader:
    """Bounds-checked cursor over a payload (mirror of the Rust
    `PayloadReader`): every take validates remaining length and raises
    instead of slicing past the end."""

    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.data):
            raise NetProtoError(
                f"net: truncated payload (wanted {n} bytes, "
                f"{len(self.data) - self.pos} left)"
            )
        chunk = self.data[self.pos : self.pos + n]
        self.pos += n
        return chunk

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self.take(4))[0]

    def i32(self) -> int:
        return struct.unpack("<i", self.take(4))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self.take(8))[0]

    def f64(self) -> float:
        return struct.unpack("<d", self.take(8))[0]

    def string(self) -> str:
        n = self.u16()
        try:
            return self.take(n).decode("utf-8")
        except UnicodeDecodeError as e:
            raise NetProtoError(f"net: invalid UTF-8 in string: {e}") from e

    def finish(self) -> None:
        if self.pos != len(self.data):
            raise NetProtoError(
                f"net: {len(self.data) - self.pos} trailing bytes after message"
            )


# ---------------------------------------------------------------------------
# message <-> payload

def msg_type(msg) -> int:
    types = {
        InferRequest: MSG_INFER_REQUEST,
        InferResponse: MSG_INFER_RESPONSE,
        Reject: MSG_REJECT,
        Failed: MSG_FAILED,
        Heartbeat: MSG_HEARTBEAT,
        HeartbeatAck: MSG_HEARTBEAT_ACK,
        StatsRequest: MSG_STATS_REQUEST,
        StatsReply: MSG_STATS_REPLY,
        Drain: MSG_DRAIN,
        DrainAck: MSG_DRAIN_ACK,
    }
    return types[type(msg)]


def encode_payload(msg) -> bytes:
    out = bytearray()
    if isinstance(msg, InferRequest):
        _put_str(out, msg.backend)
        out += struct.pack("<I", len(msg.features))
        out += bytes(1 if f else 0 for f in msg.features)
    elif isinstance(msg, InferResponse):
        _put_str(out, msg.backend)
        out += struct.pack("<I", msg.predicted)
        out += struct.pack("<I", len(msg.class_sums))
        for s in msg.class_sums:
            out += struct.pack("<i", s)
        out += struct.pack("<d", msg.service_us)
    elif isinstance(msg, (Reject, Failed)):
        _put_str(out, msg.reason)
    elif isinstance(msg, (Heartbeat, HeartbeatAck)):
        out += struct.pack("<Q", msg.nonce)
    elif isinstance(msg, StatsReply):
        for c in (
            msg.submitted,
            msg.completed,
            msg.rejected,
            msg.failed,
            msg.batches_flushed,
            msg.batched_requests,
        ):
            out += struct.pack("<Q", c)
        out += struct.pack("<I", len(msg.latency_samples))
        for x in msg.latency_samples:
            out += struct.pack("<d", x)
        out += struct.pack("<I", len(msg.batch_size_samples))
        for x in msg.batch_size_samples:
            out += struct.pack("<d", x)
    elif isinstance(msg, (StatsRequest, Drain, DrainAck)):
        pass
    else:
        raise NetProtoError(f"net: unencodable message {msg!r}")
    return bytes(out)


def decode_payload(mtype: int, payload: bytes):
    r = _Reader(payload)
    if mtype == MSG_INFER_REQUEST:
        backend = r.string()
        n = r.u32()
        raw = r.take(n)
        feats = []
        for b in raw:
            if b > 1:
                raise NetProtoError(f"net: feature byte {b} not 0/1")
            feats.append(b == 1)
        msg = InferRequest(backend, tuple(feats))
    elif mtype == MSG_INFER_RESPONSE:
        backend = r.string()
        predicted = r.u32()
        n = r.u32()
        if n > MAX_PAYLOAD // 4:
            raise NetProtoError(f"net: class-sum count {n} too large")
        sums = tuple(r.i32() for _ in range(n))
        msg = InferResponse(backend, predicted, sums, r.f64())
    elif mtype == MSG_REJECT:
        msg = Reject(r.string())
    elif mtype == MSG_FAILED:
        msg = Failed(r.string())
    elif mtype == MSG_HEARTBEAT:
        msg = Heartbeat(r.u64())
    elif mtype == MSG_HEARTBEAT_ACK:
        msg = HeartbeatAck(r.u64())
    elif mtype == MSG_STATS_REQUEST:
        msg = StatsRequest()
    elif mtype == MSG_STATS_REPLY:
        counters = [r.u64() for _ in range(6)]
        nlat = r.u32()
        if nlat > MAX_PAYLOAD // 8:
            raise NetProtoError(f"net: latency sample count {nlat} too large")
        lat = tuple(r.f64() for _ in range(nlat))
        nbat = r.u32()
        if nbat > MAX_PAYLOAD // 8:
            raise NetProtoError(f"net: batch sample count {nbat} too large")
        bat = tuple(r.f64() for _ in range(nbat))
        msg = StatsReply(*counters, lat, bat)
    elif mtype == MSG_DRAIN:
        msg = Drain()
    elif mtype == MSG_DRAIN_ACK:
        msg = DrainAck()
    else:
        raise NetProtoError(f"net: unknown message type {mtype}")
    r.finish()
    return msg


# ---------------------------------------------------------------------------
# frame codec


def encode_frame(mtype: int, payload: bytes) -> bytes:
    if len(payload) > MAX_PAYLOAD:
        raise NetProtoError(
            f"net: payload of {len(payload)} bytes exceeds MAX_PAYLOAD"
        )
    return MAGIC + struct.pack("<BBI", VERSION, mtype, len(payload)) + payload


def encode_msg(msg) -> bytes:
    """One message as a complete frame (header + payload)."""
    return encode_frame(msg_type(msg), encode_payload(msg))


def decode_frame(data: bytes) -> tuple[int, bytes, int]:
    """Parse one frame from the head of `data`; returns
    `(msg_type, payload, bytes_consumed)`. Raises `NetProtoError` on a
    malformed header and on truncation (a stream reader retries with
    more bytes; a fixed buffer treats it as a hard error)."""
    if len(data) < HEADER_LEN:
        raise NetProtoError(
            f"net: truncated frame header ({len(data)} of {HEADER_LEN} bytes)"
        )
    if data[:4] != MAGIC:
        raise NetProtoError(f"net: bad magic {data[:4]!r} (expected {MAGIC!r})")
    version, mtype, length = struct.unpack("<BBI", data[4:HEADER_LEN])
    if version != VERSION:
        raise NetProtoError(f"net: unsupported protocol version {version}")
    if length > MAX_PAYLOAD:
        raise NetProtoError(
            f"net: frame length {length} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})"
        )
    if len(data) < HEADER_LEN + length:
        raise NetProtoError(
            f"net: truncated payload ({len(data) - HEADER_LEN} of {length} bytes)"
        )
    return mtype, data[HEADER_LEN : HEADER_LEN + length], HEADER_LEN + length


def decode_msg(data: bytes):
    """Decode exactly one full-frame message from `data` (must consume
    every byte)."""
    mtype, payload, consumed = decode_frame(data)
    if consumed != len(data):
        raise NetProtoError(f"net: {len(data) - consumed} trailing bytes after frame")
    return decode_payload(mtype, payload)


# ---------------------------------------------------------------------------
# blocking stream helpers (used by the socket-pair tests)


def recv_exact(sock, n: int) -> bytes:
    """Read exactly `n` bytes from a socket; raises `NetProtoError` on
    EOF mid-read (the mid-frame-disconnect case)."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise NetProtoError(
                f"net: connection closed mid-frame ({got} of {n} bytes)"
            )
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_msg(sock):
    """Read one framed message from a blocking socket."""
    header = recv_exact(sock, HEADER_LEN)
    if header[:4] != MAGIC:
        raise NetProtoError(f"net: bad magic {header[:4]!r} (expected {MAGIC!r})")
    version, mtype, length = struct.unpack("<BBI", header[4:])
    if version != VERSION:
        raise NetProtoError(f"net: unsupported protocol version {version}")
    if length > MAX_PAYLOAD:
        raise NetProtoError(
            f"net: frame length {length} exceeds MAX_PAYLOAD ({MAX_PAYLOAD})"
        )
    return decode_payload(mtype, recv_exact(sock, length))


def write_msg(sock, msg) -> None:
    sock.sendall(encode_msg(msg))
