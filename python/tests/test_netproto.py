"""Wire-protocol mirror vs the Rust serving tier
(``rust/src/coordinator/net/``).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image.
``GOLDEN_FRAMES`` below is asserted *identically* in
``rust/src/coordinator/net/msg.rs`` (``netproto_golden_frames_match_
python_mirror``); the r5 lint probe cross-checks the hex byte
constants, so if either side changes, both fail.
"""

import socket
import struct
import threading

import pytest

from netproto import (
    HEADER_LEN,
    MAGIC,
    MAX_PAYLOAD,
    MSG_HEARTBEAT,
    VERSION,
    Drain,
    DrainAck,
    Failed,
    Heartbeat,
    HeartbeatAck,
    InferRequest,
    InferResponse,
    NetProtoError,
    Reject,
    StatsReply,
    StatsRequest,
    decode_frame,
    decode_msg,
    encode_frame,
    encode_msg,
    encode_payload,
    msg_type,
    read_msg,
    write_msg,
)

# One (message, framed bytes) pair per message type, duplicated by hand
# in the Rust suite. Frame bytes are written in hex, every message
# field in decimal — the r5 probe extracts only the hex literals.
GOLDEN_FRAMES = [
    (
        InferRequest(
            "bitparallel-mc",
            (True, False, True, True, False, False, True, False),
        ),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x01, 0x1C, 0x00, 0x00, 0x00,
            0x0E, 0x00, 0x62, 0x69, 0x74, 0x70, 0x61, 0x72, 0x61, 0x6C,
            0x6C, 0x65, 0x6C, 0x2D, 0x6D, 0x63, 0x08, 0x00, 0x00, 0x00,
            0x01, 0x00, 0x01, 0x01, 0x00, 0x00, 0x01, 0x00,
        ],
    ),
    (
        InferResponse("auto", 2, (-5, 3, 17), 123.5),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x02, 0x22, 0x00, 0x00, 0x00,
            0x04, 0x00, 0x61, 0x75, 0x74, 0x6F, 0x02, 0x00, 0x00, 0x00,
            0x03, 0x00, 0x00, 0x00, 0xFB, 0xFF, 0xFF, 0xFF, 0x03, 0x00,
            0x00, 0x00, 0x11, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x00, 0xE0, 0x5E, 0x40,
        ],
    ),
    (
        Reject("backpressure: queue depth exceeded"),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x03, 0x24, 0x00, 0x00, 0x00,
            0x22, 0x00, 0x62, 0x61, 0x63, 0x6B, 0x70, 0x72, 0x65, 0x73,
            0x73, 0x75, 0x72, 0x65, 0x3A, 0x20, 0x71, 0x75, 0x65, 0x75,
            0x65, 0x20, 0x64, 0x65, 0x70, 0x74, 0x68, 0x20, 0x65, 0x78,
            0x63, 0x65, 0x65, 0x64, 0x65, 0x64,
        ],
    ),
    (
        Failed("engine dead"),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x04, 0x0D, 0x00, 0x00, 0x00,
            0x0B, 0x00, 0x65, 0x6E, 0x67, 0x69, 0x6E, 0x65, 0x20, 0x64,
            0x65, 0x61, 0x64,
        ],
    ),
    (
        Heartbeat(81985529216486895),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x05, 0x08, 0x00, 0x00, 0x00,
            0xEF, 0xCD, 0xAB, 0x89, 0x67, 0x45, 0x23, 0x01,
        ],
    ),
    (
        HeartbeatAck(81985529216486895),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x06, 0x08, 0x00, 0x00, 0x00,
            0xEF, 0xCD, 0xAB, 0x89, 0x67, 0x45, 0x23, 0x01,
        ],
    ),
    (
        StatsRequest(),
        [0x74, 0x6D, 0x74, 0x64, 0x01, 0x07, 0x00, 0x00, 0x00, 0x00],
    ),
    (
        StatsReply(7, 5, 1, 1, 2, 5, (1.5, 2.25), (3.0,)),
        [
            0x74, 0x6D, 0x74, 0x64, 0x01, 0x08, 0x50, 0x00, 0x00, 0x00,
            0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x05, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x01, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x00, 0x00, 0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x05, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xF8, 0x3F,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x02, 0x40, 0x01, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x08, 0x40,
        ],
    ),
    (
        Drain(),
        [0x74, 0x6D, 0x74, 0x64, 0x01, 0x09, 0x00, 0x00, 0x00, 0x00],
    ),
    (
        DrainAck(),
        [0x74, 0x6D, 0x74, 0x64, 0x01, 0x0A, 0x00, 0x00, 0x00, 0x00],
    ),
]


# ---------------------------------------------------------------------------
# goldens + roundtrips


def test_golden_frames():
    assert len(GOLDEN_FRAMES) == 10, "one golden per message type"
    for msg, want in GOLDEN_FRAMES:
        assert list(encode_msg(msg)) == want, msg
        assert decode_msg(bytes(want)) == msg


def test_roundtrip_every_message_type():
    for msg, _ in GOLDEN_FRAMES:
        assert decode_msg(encode_msg(msg)) == msg


def test_roundtrip_edge_values():
    for msg in [
        InferRequest("", ()),
        InferRequest("x", tuple(i % 2 == 0 for i in range(1000))),
        InferResponse("auto-mc", 0, (), 0.0),
        InferResponse("a", 4294967295, (-2147483648, 2147483647), -1.25),
        Reject(""),
        Failed("x" * 65535),
        Heartbeat(0),
        Heartbeat(18446744073709551615),
        StatsReply(0, 0, 0, 0, 0, 0, (), ()),
        StatsReply(
            18446744073709551615, 1, 2, 3, 4, 5,
            tuple(float(i) for i in range(100)), (0.5,),
        ),
    ]:
        assert decode_msg(encode_msg(msg)) == msg


def test_frame_header_layout():
    frame = encode_msg(Heartbeat(5))
    assert frame[:4] == MAGIC
    assert frame[4] == VERSION
    assert frame[5] == MSG_HEARTBEAT
    assert struct.unpack("<I", frame[6:10])[0] == len(frame) - HEADER_LEN


def test_decode_frame_reports_consumed():
    frame = encode_msg(Drain())
    mtype, payload, consumed = decode_frame(frame + b"extra")
    assert consumed == len(frame)
    assert payload == b""


# ---------------------------------------------------------------------------
# adversarial decoding — errors must be clean NetProtoError, never a
# struct.error / IndexError crash, never a hang


def test_truncated_frames_every_prefix():
    for msg, _ in GOLDEN_FRAMES:
        frame = encode_msg(msg)
        for cut in range(len(frame)):
            with pytest.raises(NetProtoError):
                decode_msg(frame[:cut])


def test_bad_magic_rejected():
    frame = bytearray(encode_msg(Drain()))
    frame[0] ^= 0xFF
    with pytest.raises(NetProtoError, match="bad magic"):
        decode_msg(bytes(frame))


def test_bad_version_rejected():
    frame = bytearray(encode_msg(Drain()))
    frame[4] = 99
    with pytest.raises(NetProtoError, match="version"):
        decode_msg(bytes(frame))


def test_unknown_message_type_rejected():
    frame = bytearray(encode_msg(Drain()))
    frame[5] = 0xEE
    with pytest.raises(NetProtoError, match="unknown message type"):
        decode_msg(bytes(frame))


def test_oversized_length_prefix_rejected():
    header = MAGIC + struct.pack("<BBI", VERSION, MSG_HEARTBEAT, MAX_PAYLOAD + 1)
    with pytest.raises(NetProtoError, match="MAX_PAYLOAD"):
        decode_frame(header)
    with pytest.raises(NetProtoError):
        encode_frame(MSG_HEARTBEAT, b"\0" * (MAX_PAYLOAD + 1))


def test_zero_length_prefix_on_nonempty_message_rejected():
    # A zero-payload heartbeat is a truncated-payload decode error, not
    # a crash.
    header = MAGIC + struct.pack("<BBI", VERSION, MSG_HEARTBEAT, 0)
    with pytest.raises(NetProtoError, match="truncated payload"):
        decode_msg(header)


def test_trailing_garbage_rejected():
    for msg, _ in GOLDEN_FRAMES:
        with pytest.raises(NetProtoError, match="trailing"):
            decode_msg(encode_msg(msg) + b"\0")


def test_payload_internal_truncation_rejected():
    # Shorten the *payload* while keeping the declared length honest:
    # every inner cut must fail (reader bounds), none may crash.
    for msg, _ in GOLDEN_FRAMES:
        payload = encode_payload(msg)
        for cut in range(len(payload)):
            with pytest.raises(NetProtoError):
                decode_msg(encode_frame(msg_type(msg), payload[:cut]))


def test_non_boolean_feature_byte_rejected():
    payload = bytearray(encode_payload(InferRequest("a", (True,))))
    payload[-1] = 2
    with pytest.raises(NetProtoError, match="not 0/1"):
        decode_msg(encode_frame(1, bytes(payload)))


def test_invalid_utf8_backend_rejected():
    payload = struct.pack("<H", 2) + b"\xff\xfe" + struct.pack("<I", 0)
    with pytest.raises(NetProtoError, match="UTF-8"):
        decode_msg(encode_frame(1, payload))


def test_hostile_inner_counts_rejected():
    # Inner element counts larger than the payload could ever carry
    # must fail fast, not allocate or loop MAX_PAYLOAD times.
    sums = struct.pack("<H", 1) + b"a" + struct.pack("<II", 0, 0xFFFFFFFF)
    with pytest.raises(NetProtoError):
        decode_msg(encode_frame(2, sums))
    stats = struct.pack("<6Q", 0, 0, 0, 0, 0, 0) + struct.pack("<I", 0xFFFFFFFF)
    with pytest.raises(NetProtoError):
        decode_msg(encode_frame(8, stats))


# ---------------------------------------------------------------------------
# stream behaviour over a real socket pair


def _sock_pair():
    a, b = socket.socketpair()
    a.settimeout(5.0)
    b.settimeout(5.0)
    return a, b


def test_stream_roundtrip_and_interleaved_heartbeats():
    a, b = _sock_pair()
    try:
        sent = [
            Heartbeat(1),
            InferRequest("auto", (True, False)),
            Heartbeat(2),
            StatsRequest(),
            Heartbeat(3),
            Drain(),
        ]
        for m in sent:
            write_msg(a, m)
        got = [read_msg(b) for _ in sent]
        assert got == sent
    finally:
        a.close()
        b.close()


def test_stream_split_delivery():
    # One frame trickled in 1-byte writes must still decode.
    a, b = _sock_pair()
    try:
        frame = encode_msg(InferRequest("bitparallel-co", (True,) * 9))
        writer = threading.Thread(
            target=lambda: [a.sendall(bytes([x])) for x in frame]
        )
        writer.start()
        assert read_msg(b) == InferRequest("bitparallel-co", (True,) * 9)
        writer.join()
    finally:
        a.close()
        b.close()


def test_mid_frame_disconnect_is_clean_error():
    a, b = _sock_pair()
    try:
        frame = encode_msg(Heartbeat(7))
        a.sendall(frame[: len(frame) - 3])
        a.close()
        with pytest.raises(NetProtoError, match="mid-frame"):
            read_msg(b)
    finally:
        b.close()


def test_disconnect_before_any_bytes_is_clean_error():
    a, b = _sock_pair()
    try:
        a.close()
        with pytest.raises(NetProtoError, match="mid-frame"):
            read_msg(b)
    finally:
        b.close()
