"""Async clause-parallel trainer mirror vs rust/src/tm/async_train.rs.

Plain pytest (no hypothesis, no JAX) so it runs on every CI image.
Four layers, mirroring the packedtrain arrangement:

1. Stream-seed goldens: ``stream_seed(seed, epoch, lane)`` must produce
   the exact values the Rust closed form produces (asserted identically
   in ``async_train.rs::stream_seed_matches_python_mirror``).
2. Trained-model goldens: the deterministic round-robin schedule at
   threads=2 over tiny closed-form datasets — the exported masks and
   weights are hard-coded here and asserted *identically* in
   ``async_train.rs`` for both the packed and indexed engines.
3. Structural invariants, fuzzed: indexed == packed bit-for-bit under
   the deterministic schedule, TA bounds, incremental include masks ==
   recompute, per-worker index coherence, and the vote conservation law
   (asserted inside ``epoch`` itself — a lost update fails the epoch).
4. The statistical bar: the async tier is nondeterministic under real
   threading, so its accuracy (not its bits) must land within epsilon
   of the deterministic reference trainer's over seeded runs.
"""

import random

from asynctrain import (
    LANE_NEG,
    LANE_ORDER,
    LANE_WORKER0,
    AsyncCoTmTrainer,
    AsyncMultiClassTrainer,
    TrainIndex,
    stream_seed,
)
from packedtrain import (
    ClauseState,
    MultiClassTrainer,
    SplitMix64,
    TmParams,
    make_literals,
    type_i,
    type_ii,
)


def synth(f, n_samples, classes):
    """Closed-form dataset shared verbatim with the Rust unit tests."""
    feats = [
        [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(f)]
        for s in range(n_samples)
    ]
    labels = [s % classes for s in range(n_samples)]
    return feats, labels


def bits(mask):
    return "".join("1" if b else "0" for b in mask)


# ---------------------------------------------------------------------
# 1. Stream-seed goldens (asserted identically in async_train.rs).
# ---------------------------------------------------------------------

GOLDEN_STREAMS = [
    ((42, 0, 0), 0x57E1FABA65107204),
    ((42, 0, 1), 0x07782989815C29E4),
    ((42, 0, 2), 0x98B3AA3905875FB8),
    ((42, 0, 3), 0xE704EB6BC0A1009A),
    ((42, 1, 0), 0x5A0ECCCE1EDF2C68),
    ((42, 2, 5), 0x8C74E472FFA09510),
    ((7, 0, 2), 0xBCBAFD09516CDD67),
    ((9, 3, 4), 0x4A035AA2D9206AF7),
]


def test_stream_seed_goldens():
    for (seed, epoch, lane), want in GOLDEN_STREAMS:
        assert stream_seed(seed, epoch, lane) == want, (seed, epoch, lane)
    # Distinct lanes/epochs give distinct streams on the goldens, and
    # the reserved lanes are what the schedule assumes.
    values = [v for _, v in GOLDEN_STREAMS]
    assert len(set(values)) == len(values)
    assert (LANE_ORDER, LANE_NEG, LANE_WORKER0) == (0, 1, 2)


# ---------------------------------------------------------------------
# 2. Trained-model goldens (shared verbatim with async_train.rs).
#    multiclass: F=5 C=4 K=2 N=8 T=3 s=3.0, synth(5,12,2), threads=2,
#                3 deterministic epochs, seed 42
#    cotm:       F=5 C=5 K=3 N=8 T=3 s=3.0 wmax=3, synth(5,12,3),
#                threads=2, 3 deterministic epochs, seed 43
# ---------------------------------------------------------------------

GOLDEN_ASYNC_MC_MASKS = [
    ["0010001001", "0000100001", "0000110000", "0100110000"],  # class 0
    ["0000110000", "0110101010", "0000000000", "1001000001"],  # class 1
]
GOLDEN_ASYNC_CO_MASKS = [
    "0000000001",
    "1000000100",
    "0000001100",
    "0000010010",
    "0100010100",
]
GOLDEN_ASYNC_CO_WEIGHTS = [
    [1, -2, 2, -1, 2],
    [0, 1, 0, 0, -1],
    [0, 0, 1, 0, 0],
]


def test_async_multiclass_golden_model():
    feats, labels = synth(5, 12, 2)
    for engine in ("packed", "indexed"):
        tr = AsyncMultiClassTrainer(TmParams(5, 4, 2, 8, 3, 3.0), 42, 2, engine)
        model = tr.train(feats, labels, 3)
        got = [[bits(mask) for mask in cls] for cls in model]
        assert got == GOLDEN_ASYNC_MC_MASKS, engine
        assert tr.coherent() and tr.states_in_bounds(), engine


def test_async_cotm_golden_model():
    feats, labels = synth(5, 12, 3)
    for engine in ("packed", "indexed"):
        tr = AsyncCoTmTrainer(TmParams(5, 5, 3, 8, 3, 3.0, 3), 43, 2, engine)
        masks, weights = tr.train(feats, labels, 3)
        assert [bits(m) for m in masks] == GOLDEN_ASYNC_CO_MASKS, engine
        assert weights == GOLDEN_ASYNC_CO_WEIGHTS, engine
        assert tr.coherent() and tr.states_in_bounds(), engine


# ---------------------------------------------------------------------
# 3. Structural invariants, fuzzed.
# ---------------------------------------------------------------------

def test_indexed_equals_packed_under_deterministic_schedule():
    # Evaluation is exact (sweep == packed-word firing) and consumes no
    # randomness, so the two engines are bit-identical whenever the
    # schedule is — across shapes, thread counts and seeds.
    rnd = random.Random(4242)
    for case in range(12):
        f = rnd.randrange(1, 12)
        classes = rnd.randrange(1, 4)
        clauses = 2 * rnd.randrange(1, 5)
        threads = rnd.randrange(1, 5)
        seed = rnd.getrandbits(40)
        feats, labels = synth(f, 10, classes)
        p = TmParams(f, clauses, classes, 8, 3, 3.0, 3)
        a = AsyncMultiClassTrainer(p, seed, threads, "packed")
        b = AsyncMultiClassTrainer(p, seed, threads, "indexed")
        assert a.train(feats, labels, 2) == b.train(feats, labels, 2), case
        assert b.coherent(), case
        ca = AsyncCoTmTrainer(p, seed, threads, "packed")
        cb = AsyncCoTmTrainer(p, seed, threads, "indexed")
        assert ca.train(feats, labels, 2) == cb.train(feats, labels, 2), case
        assert cb.coherent(), case


def test_invariants_hold_across_thread_counts():
    # TA counters in bounds, incremental masks equal recompute, indexes
    # coherent, and the vote conservation law (checked inside epoch())
    # — for 1, 2, 3 and 8 workers, including workers with no clauses.
    feats, labels = synth(7, 20, 3)
    p = TmParams(7, 8, 3, 16, 4, 3.0, 4)
    for threads in (1, 2, 3, 8):
        for engine in ("packed", "indexed"):
            tr = AsyncMultiClassTrainer(p, 99, threads, engine)
            tr.train(feats, labels, 3)
            assert tr.coherent() and tr.states_in_bounds(), (threads, engine)
            co = AsyncCoTmTrainer(p, 99, threads, engine)
            _, weights = co.train(feats, labels, 3)
            assert co.coherent() and co.states_in_bounds(), (threads, engine)
            assert all(abs(w) <= p.max_weight for row in weights for w in row)


def test_more_threads_than_clauses_leaves_empty_partitions_working():
    feats, labels = synth(4, 8, 2)
    tr = AsyncMultiClassTrainer(TmParams(4, 2, 2, 8, 3, 3.0), 3, 6, "indexed")
    model = tr.train(feats, labels, 2)
    assert len(model) == 2 and len(model[0]) == 2
    assert tr.coherent() and tr.states_in_bounds()


def test_train_index_incremental_maintenance_matches_rebuild():
    # Unit level: fired flags match direct training-time evaluation, and
    # replaying Type I/II diffs keeps the index equal to a fresh build.
    rnd = random.Random(31)
    for _ in range(20):
        f = rnd.randrange(1, 20)
        n = 8
        rng = SplitMix64(rnd.getrandbits(63))
        states = [
            ClauseState.init(2 * f, n, rng)
            for _ in range(rnd.randrange(1, 6))
        ]
        index = TrainIndex(states, n, 2 * f)
        for _ in range(30):
            x = [rnd.random() < 0.5 for _ in range(f)]
            lits = make_literals(x)
            flags = index.fired_flags(lits)
            for ci, cl in enumerate(states):
                assert flags[ci] == cl.fires_reference(lits, n), ci
            ci = rnd.randrange(len(states))
            old = list(states[ci].include_words)
            if rnd.random() < 0.5:
                type_i(states[ci], lits, rnd.random() < 0.5, n, 3.0, rng)
            else:
                type_ii(states[ci], lits, n)
            index.apply_diff(ci, old, states[ci].include_words)
            assert index.coherent(states)


# ---------------------------------------------------------------------
# 4. The statistical accuracy-parity bar: async vs reference, within
#    epsilon over seeded runs (the async tier's bar — bit-identity is
#    deliberately NOT promised once real threads race).
# ---------------------------------------------------------------------

def blobs(n, f, classes, flip, seed):
    """Prototype-per-class dataset with bit-flip noise (statistical
    bar only — does not need to match any Rust dataset bit-for-bit)."""
    rnd = random.Random(seed)
    protos = [[rnd.random() < 0.5 for _ in range(f)] for _ in range(classes)]
    feats, labels = [], []
    for s in range(n):
        y = s % classes
        feats.append([b != (rnd.random() < flip) for b in protos[y]])
        labels.append(y)
    return feats, labels


def clause_fires_infer(mask, lits):
    """Inference-time semantics: an empty clause outputs 0."""
    if not any(mask):
        return False
    return all(lit for m, lit in zip(mask, lits) if m)


def mc_accuracy(model, feats, labels):
    correct = 0
    for x, y in zip(feats, labels):
        lits = make_literals(x)
        sums = []
        for cls in model:
            s = 0
            for j, mask in enumerate(cls):
                if clause_fires_infer(mask, lits):
                    s += 1 if j % 2 == 0 else -1
            sums.append(s)
        # argmax, lowest index on ties (infer.rs predict_argmax).
        pred = max(range(len(sums)), key=lambda c: (sums[c], -c))
        correct += pred == y
    return correct / len(labels)


def test_async_accuracy_parity_with_reference_trainer():
    eps = 0.15
    p = TmParams(20, 10, 3, 32, 8, 3.0)
    for seed in (1, 2, 3):
        feats, labels = blobs(90, 20, 3, 0.05, seed)
        ref = MultiClassTrainer(p, seed, "packed").train(feats, labels, 10)
        asy = AsyncMultiClassTrainer(p, seed, 4).train(feats, labels, 10)
        ra = mc_accuracy(ref, feats, labels)
        aa = mc_accuracy(asy, feats, labels)
        # The reference tier must have actually learned something, or
        # the parity bar is vacuous.
        assert ra > 0.6, (seed, ra)
        assert abs(ra - aa) <= eps, (seed, ra, aa)
