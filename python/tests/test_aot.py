"""AOT artifact smoke tests: lowering emits parseable HLO text + manifest."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_artifacts(out, features=4, clauses=6, classes=3,
                                   batches=[1, 2])
    return out, manifest


def test_manifest_lists_all_variants(artifacts):
    out, manifest = artifacts
    names = set(manifest["artifacts"])
    assert names == {
        "multiclass_tm_b1", "cotm_b1", "clause_only_b1",
        "multiclass_tm_b2", "cotm_b2", "clause_only_b2",
    }
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest


def test_hlo_text_is_emitted_and_looks_like_hlo(artifacts):
    out, manifest = artifacts
    for meta in manifest["artifacts"].values():
        path = os.path.join(out, meta["file"])
        text = open(path).read()
        assert "HloModule" in text
        assert "ENTRY" in text
        # return_tuple=True -> root is a tuple (rust unwraps via to_tuple1)
        assert "tuple(" in text or "ROOT" in text


def test_manifest_shapes_consistent(artifacts):
    _, manifest = artifacts
    f, c, k = manifest["features"], manifest["clauses"], manifest["classes"]
    m = manifest["artifacts"]["multiclass_tm_b2"]
    assert m["args"] == [[2, f], [k, c, 2 * f], ]
    assert m["out"] == [2, k]
    co = manifest["artifacts"]["cotm_b2"]
    assert co["args"] == [[2, f], [c, 2 * f], [k, c]]


def test_no_custom_calls_in_hlo(artifacts):
    """interpret=True must lower to plain HLO ops the CPU client can run —
    a Mosaic custom-call here would break the rust runtime."""
    out, manifest = artifacts
    for meta in manifest["artifacts"].values():
        text = open(os.path.join(out, meta["file"])).read()
        assert "custom-call" not in text, meta["file"]
