"""The analyzer's own test suite (PR 7).

Three layers:

* fixture conformance — every rule r1-r7 must fire on its known-bad
  mini-repo under ``fixtures/analysis/`` and stay silent on its
  known-good twin, so a rule that rots into always-pass (or
  always-fail) is caught here, not in review;
* a meta-test — every rule module registers the full contract surface
  (id, title, fixture pair, check callable) and the fixture pair
  actually exists on disk;
* live-tree checks — the real repo is lint-clean end to end, and the
  r7 ratchet pin matches the tree it claims to describe.

Plain pytest, no JAX, no hypothesis: this file runs on every CI image.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from analysis import engine
from analysis.rules import (
    ALL_RULES,
    r1_lock_discipline,
    r7_ratchet,
    r8_compile_pipeline,
)

REPO = Path(__file__).resolve().parents[2]
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"

_IDS = [rule.RULE for rule in ALL_RULES]


# ---------------------------------------------------------------------------
# fixture conformance: bad fires, good is silent


@pytest.mark.parametrize("rule", ALL_RULES, ids=_IDS)
def test_rule_fires_on_known_bad_fixture(rule):
    tree = engine.Tree(FIXTURES / rule.FIXTURE_BAD, fixture=True)
    findings = rule.check(tree)
    assert findings, (
        f"{rule.RULE} reported nothing on its known-bad fixture "
        f"{rule.FIXTURE_BAD} — the rule has rotted into always-pass"
    )
    assert all(f.rule == rule.RULE for f in findings)
    for f in findings:
        # Findings must render as clickable file:line references.
        assert f.render().startswith(f"{f.path}:{f.line} [{rule.RULE}]")
        assert f.line >= 1


@pytest.mark.parametrize("rule", ALL_RULES, ids=_IDS)
def test_rule_is_silent_on_known_good_fixture(rule):
    tree = engine.Tree(FIXTURES / rule.FIXTURE_GOOD, fixture=True)
    findings = rule.check(tree)
    assert findings == [], (
        f"{rule.RULE} fired on its known-good fixture "
        f"{rule.FIXTURE_GOOD}: " + "; ".join(f.render() for f in findings)
    )


# ---------------------------------------------------------------------------
# meta-test: every rule registers the full contract surface


@pytest.mark.parametrize("rule", ALL_RULES, ids=_IDS)
def test_rule_registers_fixture_pair(rule):
    for attr in ("RULE", "TITLE", "FIXTURE_GOOD", "FIXTURE_BAD"):
        assert isinstance(getattr(rule, attr), str) and getattr(rule, attr)
    assert callable(rule.check)
    for name in (rule.FIXTURE_GOOD, rule.FIXTURE_BAD):
        root = FIXTURES / name
        assert root.is_dir(), f"{rule.RULE} fixture {name} missing"
        assert any(p.is_file() for p in root.rglob("*")), (
            f"{rule.RULE} fixture {name} is empty"
        )


def test_rule_ids_and_fixtures_are_unique():
    assert len(set(_IDS)) == len(ALL_RULES)
    names = [r.FIXTURE_GOOD for r in ALL_RULES] + [
        r.FIXTURE_BAD for r in ALL_RULES
    ]
    assert len(set(names)) == len(names)


# ---------------------------------------------------------------------------
# suppression semantics


def _mini_repo(tmp_path, body):
    # util/ is outside the r7 ratchet scope, so the only findings are
    # the ones the body provokes.
    src = tmp_path / "rust" / "src" / "util"
    src.mkdir(parents=True)
    (src / "sync.rs").write_text(body, encoding="utf-8")
    return engine.Tree(tmp_path, fixture=True)


def test_reasoned_allow_suppresses_the_finding(tmp_path):
    tree = _mini_repo(
        tmp_path,
        "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n"
        "    // lint:allow(r1) this mini-repo exercises suppression\n"
        "    *m.lock().unwrap()\n"
        "}\n",
    )
    assert engine.run(tree, rules=[r1_lock_discipline]) == []


def test_reasonless_allow_is_its_own_finding(tmp_path):
    tree = _mini_repo(
        tmp_path,
        "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n"
        "    // lint:allow(r1)\n"
        "    *m.lock().unwrap()\n"
        "}\n",
    )
    findings = engine.run(tree, rules=[r1_lock_discipline])
    # No reason => no suppression: the original finding survives AND
    # the naked directive is reported.
    assert [f.rule for f in findings] == ["allow", "r1"]


def test_allow_for_the_wrong_rule_does_not_suppress(tmp_path):
    tree = _mini_repo(
        tmp_path,
        "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n"
        "    // lint:allow(r4) wrong rule id\n"
        "    *m.lock().unwrap()\n"
        "}\n",
    )
    assert "r1" in {f.rule for f in engine.run(tree, rules=[r1_lock_discipline])}


# ---------------------------------------------------------------------------
# r8 specifics: finding placement and the allow escape hatch


def _r8_repo(tmp_path, server_body):
    src = tmp_path / "rust" / "src" / "coordinator"
    src.mkdir(parents=True)
    (src / "server.rs").write_text(server_body, encoding="utf-8")
    return engine.Tree(tmp_path, fixture=True)


def test_r8_pins_the_offending_call_line(tmp_path):
    tree = _r8_repo(
        tmp_path,
        "fn build(m: &MultiClassTmModel) -> Result<Engines> {\n"
        "    let bp = BitParallelMulticlass::from_model(m)?;\n"
        "    Ok(Engines { bp })\n"
        "}\n",
    )
    findings = r8_compile_pipeline.check(tree)
    assert [(f.path, f.line) for f in findings] == [
        ("rust/src/coordinator/server.rs", 2),
        ("rust/src/coordinator/server.rs", 1),
    ]


def test_r8_reasoned_allow_suppresses_a_direct_from_model(tmp_path):
    tree = _r8_repo(
        tmp_path,
        "fn build(m: &MultiClassTmModel) -> Result<Engines> {\n"
        "    let compiled = ModelCompiler::default().compile_multiclass(m)?;\n"
        "    let bp = BitParallelMulticlass::from_compiled(&compiled)?;\n"
        "    // lint:allow(r8) migration shim until the legacy path retires\n"
        "    let legacy = IndexedMulticlass::from_model(m)?;\n"
        "    Ok(Engines { bp, legacy })\n"
        "}\n",
    )
    assert engine.run(tree, rules=[r8_compile_pipeline]) == []


def test_r8_ignores_from_model_under_cfg_test(tmp_path):
    tree = _r8_repo(
        tmp_path,
        "fn build(m: &MultiClassTmModel) -> Result<Engines> {\n"
        "    let compiled = ModelCompiler::default().compile_multiclass(m)?;\n"
        "    Ok(Engines { bp: BitParallelMulticlass::from_compiled(&compiled)? })\n"
        "}\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    #[test]\n"
        "    fn wrapper_still_works() {\n"
        "        IndexedMulticlass::from_model(&tiny()).unwrap();\n"
        "    }\n"
        "}\n",
    )
    assert r8_compile_pipeline.check(tree) == []


# ---------------------------------------------------------------------------
# live tree: the repo itself holds every invariant it documents


def test_live_tree_is_lint_clean():
    findings = engine.run(engine.Tree(REPO))
    assert findings == [], "live tree has lint findings:\n" + "\n".join(
        f.render() for f in findings
    )


def test_ratchet_pin_matches_live_tree():
    pinned = json.loads((REPO / r7_ratchet.RATCHET).read_text("utf-8"))
    assert pinned == r7_ratchet.live_counts(engine.Tree(REPO)), (
        "ratchet.json is stale — run python3 -m analysis --update-ratchet "
        "and review the diff"
    )


def test_cli_entrypoint_exits_zero_on_live_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "analysis", str(REPO)],
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "python"), "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
        check=False,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "lint: OK" in proc.stdout
