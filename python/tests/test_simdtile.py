"""Tiled bit-sliced batch layout mirror vs the Rust tiles (tm/bitpack.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. The golden
geometry, words, fingerprint and clause-output words below are asserted
*identically* in ``rust/src/tm/bitpack.rs``
(``tiled_layout_golden_vectors_match_python_mirror``); both sides build
them from the same closed-form formulas, so if either implementation's
tile math drifts, both suites fail.
"""

import random

from simdtile import (
    TILE_BLOCKS,
    WORD_BITS,
    TiledBatch,
    clause_outputs,
    evaluate_block,
    evaluate_tile,
    fnv1a64_words,
    pack_literals,
    ref_clause_output,
    tile_geometry,
    words_for,
)

# ---------------------------------------------------------------------
# The shared golden scheme (formulas mirrored in bitpack.rs):
#   F=3, 200 samples; feature i of sample s = (i*i + 3*i*s + 2*s)%7 < 3
#   clause includes literal l iff (3*l) % 5 == 0  ->  literals [0, 5]
# ---------------------------------------------------------------------

F = 3


def golden_rows():
    return [
        [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(F)]
        for s in range(200)
    ]


GOLDEN_INCLUDE = [(3 * l) % 5 == 0 for l in range(2 * F)]
GOLDEN_LITERALS = [0, 5]
GOLDEN_FNV = 0x6C6E8C1EA8439D9E
GOLDEN_TILE_OUT = [
    0x83060C183060C183,
    0xC183060C183060C1,
    0x60C183060C183060,
    0x0000000000000030,
]


def test_words_for_boundaries():
    assert words_for(0) == 0
    assert words_for(1) == 1
    assert words_for(64) == 1
    assert words_for(65) == 2
    assert words_for(129) == 3


def test_tile_geometry():
    # Small batches never pad out to a full tile; big ones split at
    # TILE_BLOCKS with a shorter final tile.
    assert tile_geometry(0) == (1, 1, 1)
    assert tile_geometry(1) == (1, 1, 1)
    assert tile_geometry(64) == (1, 1, 1)
    assert tile_geometry(65) == (2, 2, 1)
    assert tile_geometry(512) == (8, 8, 1)
    assert tile_geometry(513) == (9, 8, 2)
    assert tile_geometry(600) == (10, 8, 2)
    assert tile_geometry(64 * 17) == (17, 8, 3)
    assert TILE_BLOCKS == 8


def test_golden_vectors():
    b = TiledBatch(golden_rows(), F)
    assert (b.blocks, b.stride, b.tiles) == (4, 4, 1)
    assert len(b.data) == 24
    # Asserted identically in bitpack.rs.
    assert fnv1a64_words(b.data) == GOLDEN_FNV
    assert b.lit_word(0, 0) == 0x93264C993264C993
    assert b.lit_word(1, 1) == 0x366CD9B366CD9B36
    assert b.lit_word(3, 4) == 0x0000000000000087
    assert b.valid_mask(3) == 0xFF

    assert [l for l, v in enumerate(GOLDEN_INCLUDE) if v] == GOLDEN_LITERALS
    assert evaluate_tile(b, GOLDEN_LITERALS, 0) == GOLDEN_TILE_OUT


def test_golden_outputs_match_direct_reference():
    # The pinned words themselves encode the right clause outputs.
    b = TiledBatch(golden_rows(), F)
    got = clause_outputs(b, GOLDEN_LITERALS)
    want = [ref_clause_output(GOLDEN_INCLUDE, r) for r in golden_rows()]
    assert got == want
    # Non-vacuous: the golden clause both fires and stays silent.
    assert any(want) and not all(want)


def test_lit_lane_is_contiguous_view_of_lit_word():
    rows = [
        [(s * 2654435761 >> i) & 1 == 1 for i in range(5)] for s in range(600)
    ]
    b = TiledBatch(rows, 5)
    assert (b.blocks, b.stride, b.tiles) == (10, 8, 2)
    assert b.tile_blocks(0) == 8
    assert b.tile_blocks(1) == 2
    for t in range(b.tiles):
        for l in range(2 * 5):
            lane = b.lit_lane(t, l)
            assert lane == [b.lit_word(t * 8 + j, l) for j in range(len(lane))]
    # Every bit equals the per-sample literal value.
    for s, row in enumerate(rows):
        for i, fv in enumerate(row):
            lit = 2 * i + (0 if fv else 1)
            assert (b.lit_word(s // WORD_BITS, lit) >> (s % WORD_BITS)) & 1 == 1


def test_pack_literals_sets_one_bit_per_pair():
    # x0=1 -> bit 0, x1=0 -> bit 3 (¬x1), x2=1 -> bit 4.
    words = pack_literals([True, False, True])
    assert words == [0b11001]
    assert pack_literals([]) == []


def test_empty_clause_outputs_zero():
    b = TiledBatch([[True, False], [False, True]], 2)
    assert evaluate_tile(b, [], 0) == [0]
    assert evaluate_block(b, [], 0) == 0
    assert clause_outputs(b, []) == [False, False]


def test_padding_bits_stay_zero_in_tail_block():
    # An always-firing clause must still leave padding bits clear.
    b = TiledBatch([[True, False]] * 3, 2)
    assert evaluate_tile(b, [0], 0) == [0b111]
    assert evaluate_block(b, [0], 0) == 0b111


def test_differential_vs_direct_reference():
    # Randomized sweep over word-boundary widths, block-boundary batch
    # sizes and densities from all-exclude to near-full; the tiled
    # evaluator and the single-word block walk must both equal the
    # direct per-sample reference.
    rng = random.Random(20260801)
    for case in range(200):
        f = rng.choice([1, 2, 5, 31, 32, 33, 63, 64, 65])
        n = rng.choice([1, 2, 63, 64, 65, 127, 128, 130, 513, 600])
        rows = [[rng.random() < 0.5 for _ in range(f)] for _ in range(n)]
        density = rng.choice([0.0, 0.05, 0.3, 0.9])
        include = [rng.random() < density for _ in range(2 * f)]
        lits = [l for l, v in enumerate(include) if v]
        b = TiledBatch(rows, f)
        want = [ref_clause_output(include, r) for r in rows]
        assert clause_outputs(b, lits) == want, (case, f, n)
        for blk in range(b.blocks):
            w = evaluate_block(b, lits, blk)
            lo = blk * WORD_BITS
            for s in range(lo, min(lo + WORD_BITS, n)):
                assert ((w >> (s - lo)) & 1 == 1) == want[s], (case, s)


def test_row_width_mismatch_rejected():
    try:
        TiledBatch([[True, False], [True]], 2)
    except ValueError:
        pass
    else:
        raise AssertionError("width mismatch must raise")
