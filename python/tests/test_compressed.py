"""Compressed include-list walk mirror vs the Rust engines (tm/compressed.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. The golden
models, samples, class sums and frequency-reordered walk lists below are
asserted *identically* in ``rust/src/tm/compressed.rs``
(``golden_vectors_match_python_mirror`` /
``golden_frequency_reorder_matches_python_mirror``); both sides build
them from the same closed-form formulas, so if either implementation
drifts, both suites fail.
"""

import random

from compressed import (
    PACKED_VS_COMPRESSED_DENSITY,
    PACKED_VS_INDEXED_DENSITY,
    CompressedCotm,
    CompressedModel,
    CompressedMulticlass,
    select_engine,
)
from invindex import ref_cotm_class_sums, ref_multiclass_class_sums

# ---------------------------------------------------------------------
# The shared golden scheme (formulas mirrored in compressed.rs — the
# same models/samples the invindex mirror pins, so all four engine
# families golden-vector to one table):
#   multiclass: F=9, C=4/class, K=3; include(k,j,l) = (3l+5j+7k)%11 == 0
#   cotm:       F=9, C=6, K=3; include(j,l) = (5l+3j)%7 == 0,
#               weight(k,j) = (j+2k)%7 - 3
#   sample s:   feature i = (i*i + 3*i*s + 2*s) % 7 < 3
# ---------------------------------------------------------------------

F = 9
LITS = 2 * F

GOLDEN_MC_CLAUSES = [
    [[(3 * l + 5 * j + 7 * k) % 11 == 0 for l in range(LITS)] for j in range(4)]
    for k in range(3)
]
GOLDEN_CO_CLAUSES = [
    [(5 * l + 3 * j) % 7 == 0 for l in range(LITS)] for j in range(6)
]
GOLDEN_CO_WEIGHTS = [[(j + 2 * k) % 7 - 3 for j in range(6)] for k in range(3)]


def golden_sample(s):
    return [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(F)]


GOLDEN_MC_SUMS = [
    [1, 0, -1],
    [0, -1, 2],
    [0, -1, 0],
    [0, 0, 0],
    [-1, -1, 1],
    [0, 0, 0],
]
GOLDEN_CO_SUMS = [
    [-2, 0, 2],
    [-6, 0, 6],
    [0, 2, -3],
    [3, 2, -6],
    [-3, -1, 1],
    [3, 2, -6],
]

# The frequency-reorder golden (mirrored in compressed.rs): F=3, include
# lists [0,4], [2,4], [4], [0,2,4,5] — literal frequencies 0:2, 2:2,
# 4:4, 5:1, so the reorder is a real permutation.
REORDER_LISTS = [[0, 4], [2, 4], [4], [0, 2, 4, 5]]
REORDER_MASKS = [
    [lit in lst for lit in range(6)] for lst in REORDER_LISTS
]
REORDER_WANT = [[4, 0], [4, 2], [4], [4, 0, 2, 5]]


def test_multiclass_golden_vectors():
    eng = CompressedMulticlass(GOLDEN_MC_CLAUSES)
    for s in range(6):
        x = golden_sample(s)
        assert eng.class_sums(x) == GOLDEN_MC_SUMS[s], s
        # The goldens themselves match the direct reference, so all
        # tiers (Rust compressed, Rust scalar, this mirror) pin the
        # same semantics.
        assert ref_multiclass_class_sums(GOLDEN_MC_CLAUSES, x) == GOLDEN_MC_SUMS[s], s


def test_cotm_golden_vectors():
    eng = CompressedCotm(GOLDEN_CO_CLAUSES, GOLDEN_CO_WEIGHTS)
    for s in range(6):
        x = golden_sample(s)
        assert eng.class_sums(x) == GOLDEN_CO_SUMS[s], s
        assert (
            ref_cotm_class_sums(GOLDEN_CO_CLAUSES, GOLDEN_CO_WEIGHTS, x)
            == GOLDEN_CO_SUMS[s]
        ), s


def test_golden_frequency_reorder():
    # The deterministic reorder key (descending global frequency, ties
    # by ascending literal id) — compressed.rs asserts these exact
    # lists in golden_frequency_reorder_matches_python_mirror.
    cm = CompressedModel(3, REORDER_MASKS)
    assert [cm.included(c) for c in range(4)] == REORDER_LISTS
    assert cm.literal_frequencies() == [2, 0, 2, 0, 4, 1]
    cm.reorder_by_frequency()
    assert [cm.included(c) for c in range(4)] == REORDER_WANT
    # Both golden models reorder to themselves (uniform in-clause
    # frequencies), which the sums goldens rely on.
    g = CompressedModel(F, GOLDEN_CO_CLAUSES)
    before = [g.included(c) for c in range(g.num_clauses())]
    g.reorder_by_frequency()
    assert [g.included(c) for c in range(g.num_clauses())] == before


def test_walk_order_is_output_invariant():
    # Sorted vs frequency-reordered walks are the same AND over the
    # same set: firing identical on all 8 inputs of the reorder model.
    sorted_m = CompressedModel(3, REORDER_MASKS)
    hot = CompressedModel(3, REORDER_MASKS)
    hot.reorder_by_frequency()
    for bits in range(8):
        x = [bool((bits >> i) & 1) for i in range(3)]
        assert sorted_m.sweep(x) == hot.sweep(x), bits


def test_hand_worked_multiclass_oracle():
    # The same hand-worked example as rust/src/tm/infer.rs and
    # python/tests/test_model.py: both layers must agree on it.
    clauses = [
        [
            [True, False, False, False],   # class0 clause0 (+): x0
            [False, False, False, True],   # class0 clause1 (-): not x1
        ],
        [
            [False, True, False, False],   # class1 clause0 (+): not x0
            [False, False, True, False],   # class1 clause1 (-): x1
        ],
    ]
    eng = CompressedMulticlass(clauses)
    assert eng.class_sums([True, False]) == [0, 0]
    assert eng.class_sums([True, True]) == [1, -1]


def test_hand_worked_cotm_oracle():
    clauses = [
        [True, False, False, False],   # clause0: x0
        [False, False, True, False],   # clause1: x1
    ]
    weights = [[3, -2], [-1, 4]]
    eng = CompressedCotm(clauses, weights)
    assert eng.class_sums([True, True]) == [1, 3]
    assert eng.class_sums([True, False]) == [3, -1]
    assert eng.class_sums([False, False]) == [0, 0]


def test_empty_clause_never_fires():
    # All-exclude clauses compress to empty lists — the "empty clause
    # outputs 0 at inference" convention.
    eng = CompressedCotm([[False] * 4, [False] * 4], [[5, 7], [1, 2]])
    assert eng.class_sums([True, True]) == [0, 0]
    assert eng.class_sums([False, False]) == [0, 0]


def test_contradictory_clause_never_fires():
    # x0 AND not-x0 always early-exits on one of the pair.
    eng = CompressedCotm([[True, True, False, False]], [[5], [5]])
    for x in ([True, True], [False, False], [True, False]):
        assert eng.class_sums(x) == [0, 0], x


def test_all_include_clause_fires_only_on_its_witness():
    # One literal per pair: the longest non-contradictory walk. Fires
    # exactly on the witness, early-exits on every single-bit flip.
    lists = [2 * i + (i % 2) for i in range(4)]  # x0, !x1, x2, !x3
    clauses = [[lit in lists for lit in range(8)]]
    eng = CompressedCotm(clauses, [[2], [-1]])
    witness = [True, False, True, False]
    assert eng.class_sums(witness) == [2, -1]
    for flip in range(4):
        x = list(witness)
        x[flip] = not x[flip]
        assert eng.class_sums(x) == [0, 0], flip


def test_density_and_postings_accounting():
    cm = CompressedModel(F, GOLDEN_CO_CLAUSES)
    included = sum(sum(m) for m in GOLDEN_CO_CLAUSES)
    assert cm.postings() == included
    assert abs(cm.density() - included / (6 * LITS)) < 1e-12
    assert CompressedModel(2, [[False] * 4]).density() == 0.0
    assert CompressedModel(0, []).density() == 0.0


def test_select_engine_is_a_pure_three_way_threshold():
    it, ct = PACKED_VS_INDEXED_DENSITY, PACKED_VS_COMPRESSED_DENSITY
    # Same table as compressed.rs select_engine_is_a_pure_three_way_threshold.
    assert select_engine(0.01, it, ct) == "indexed"
    assert select_engine(it, it, ct) == "indexed"
    assert select_engine(0.1, it, ct) == "compressed"
    assert select_engine(ct, it, ct) == "compressed"
    assert select_engine(0.5, it, ct) == "packed"
    assert select_engine(0.0, 0.0, 0.0) == "indexed"
    assert select_engine(0.1, 0.0, 0.0) == "packed"
    assert select_engine(0.1, 0.0, 1.0) == "compressed"
    assert select_engine(1.0, 1.0, 0.0) == "indexed"
    assert select_engine(0.9, 0.0, 0.9) == "compressed"
    # Inverted pairs stay total: indexed wins its range first.
    assert select_engine(0.3, 0.5, 0.1) == "indexed"
    assert select_engine(0.7, 0.5, 0.1) == "packed"


def _random_masks(rng, n, lits, density):
    return [[rng.random() < density for _ in range(lits)] for _ in range(n)]


def test_randomized_differential_multiclass():
    # 300 random models spanning all-exclude to dense clauses: the
    # early-exit walk must equal the direct evaluator sample-for-sample.
    rng = random.Random(0xE7EA1)
    for case in range(300):
        f = rng.randint(1, 24)
        c = 2 * rng.randint(1, 4)
        k = rng.randint(2, 4)
        density = rng.choice([0.0, 0.05, 0.15, 0.4, 0.8, 1.0])
        clauses = [_random_masks(rng, c, 2 * f, density) for _ in range(k)]
        eng = CompressedMulticlass(clauses)
        for _ in range(4):
            x = [rng.random() < 0.5 for _ in range(f)]
            assert eng.class_sums(x) == ref_multiclass_class_sums(clauses, x), (
                case, f, c, k, density,
            )


def test_randomized_differential_cotm():
    rng = random.Random(0xE7EA2)
    for case in range(300):
        f = rng.randint(1, 24)
        c = rng.randint(1, 8)
        k = rng.randint(2, 4)
        density = rng.choice([0.0, 0.05, 0.15, 0.4, 0.8, 1.0])
        clauses = _random_masks(rng, c, 2 * f, density)
        weights = [[rng.randint(-7, 7) for _ in range(c)] for _ in range(k)]
        eng = CompressedCotm(clauses, weights)
        for _ in range(4):
            x = [rng.random() < 0.5 for _ in range(f)]
            assert eng.class_sums(x) == ref_cotm_class_sums(clauses, weights, x), (
                case, f, c, k, density,
            )


def test_randomized_compressed_agrees_with_invindex_mirror():
    # Cross-mirror differential: the compressed walk and the counter
    # sweep are two event-driven readings of the same semantics.
    from invindex import IndexedMulticlass

    rng = random.Random(0xE7EA3)
    for case in range(100):
        f = rng.randint(1, 16)
        c = 2 * rng.randint(1, 3)
        k = rng.randint(2, 4)
        density = rng.choice([0.0, 0.1, 0.3, 0.6])
        clauses = [_random_masks(rng, c, 2 * f, density) for _ in range(k)]
        compressed = CompressedMulticlass(clauses)
        indexed = IndexedMulticlass(clauses)
        for _ in range(3):
            x = [rng.random() < 0.5 for _ in range(f)]
            assert compressed.class_sums(x) == indexed.class_sums(x), (case, f)
