"""Consistent-hash ring mirror vs the Rust coordinator (shard.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. The golden
vectors below are asserted *identically* in
``rust/src/coordinator/shard.rs``; if either side changes, both fail.
"""

from collections import Counter

from hashring import (
    DEFAULT_VNODES,
    HashRing,
    fnv1a64,
    hash_bytes,
    hash_features,
    hash_key,
    mix64,
    vnode_point,
)


def test_fnv1a64_golden_vectors():
    assert fnv1a64(b"") == 0xCBF29CE484222325
    assert fnv1a64(bytes([0])) == 0xAF63BD4C8601B7DF
    assert fnv1a64(bytes([1, 0, 1, 1])) == 0xAD2E2F77479B38DA


def test_ring_hash_golden_vectors():
    assert hash_bytes(b"") == 0xF52A15E9A9B5E89B
    assert hash_bytes(bytes([1, 0, 1, 1])) == 0x99D31E75C555AF01
    assert hash_key(0) == 0x813F0174A2367C13
    assert hash_key(12345) == 0xAA08DA7926F8F279
    assert vnode_point(0, 0) == 0x68752350AE1D483F
    assert vnode_point(3, 17) == 0x83C60DBA0F78C403
    feats = [True, False, True, True, False, False, True, False]
    assert hash_features(feats) == 0xE6B1FF75897B44FC


def test_ring_routing_golden_vectors():
    ring4 = HashRing(4, DEFAULT_VNODES)
    for key, want in [(0, 0), (1, 1), (2, 0), (42, 0),
                      (12345, 3), (999_999_999, 0)]:
        assert ring4.shard_for_key(key) == want, key
    feats = [True, False, True, True, False, False, True, False]
    assert ring4.shard_for_features(feats) == 3
    ring3 = HashRing(3, DEFAULT_VNODES)
    for key, want in [(0, 0), (7, 1), (100, 2)]:
        assert ring3.shard_for_key(key) == want, key


def test_ring_walk_golden_vectors():
    ring4 = HashRing(4, DEFAULT_VNODES)
    for key, want in [(0, [0, 2, 1, 3]), (1, [1, 0, 2, 3]),
                      (12345, [3, 0, 2, 1])]:
        assert ring4.walk_from_hash(hash_key(key)) == want, key
    feats = [True, False, True, True, False, False, True, False]
    assert ring4.walk_from_hash(hash_features(feats)) == [3, 1, 2, 0]
    ring3 = HashRing(3, DEFAULT_VNODES)
    for key, want in [(0, [0, 2, 1]), (7, [1, 0, 2]), (100, [2, 0, 1])]:
        assert ring3.walk_from_hash(hash_key(key)) == want, key
    assert HashRing(1, DEFAULT_VNODES).walk_from_hash(hash_key(0)) == [0]


def test_walk_starts_at_owner_and_is_a_permutation():
    # The failover order must begin at the routing owner and visit
    # every shard exactly once.
    for shards in [1, 2, 3, 5, 8]:
        ring = HashRing(shards, 32)
        for k in range(500):
            h = hash_key(k)
            walk = ring.walk_from_hash(h)
            assert walk[0] == ring.shard_for_hash(h)
            assert sorted(walk) == list(range(shards)), (shards, k)


def test_ring_is_deterministic():
    a = HashRing(5, 32)
    b = HashRing(5, 32)
    assert a.points == b.points
    for k in range(2000):
        assert a.shard_for_key(k) == b.shard_for_key(k)


def test_ring_wraps_past_top():
    for shards in [1, 2, 3, 4, 8]:
        ring = HashRing(shards, DEFAULT_VNODES)
        assert ring.shard_for_hash((1 << 64) - 1) == ring.shard_for_hash(0)


def test_mix64_improves_balance():
    # The mixer is load-bearing: sequential keys must spread, and every
    # shard must own a share of a uniform key stream within a loose
    # envelope of fair (measured <= ~1.25x at 128 vnodes/shard).
    for shards in [2, 3, 4, 8]:
        ring = HashRing(shards, DEFAULT_VNODES)
        counts = Counter(ring.shard_for_key(k) for k in range(10_000))
        fair = 10_000 / shards
        assert set(counts) == set(range(shards)), counts
        for s, n in counts.items():
            assert 0.5 * fair < n < 1.5 * fair, (shards, s, n, fair)


def test_feature_routing_matches_key_encoding():
    # Feature vectors hash their 0/1 bytes — the same bytes through
    # hash_bytes must agree, and routing must be insensitive to the
    # Python bool/int representation.
    ring = HashRing(4, DEFAULT_VNODES)
    feats = [True, False, False, True, True]
    as_ints = [1, 0, 0, 1, 1]
    assert hash_features(feats) == hash_bytes(bytes(as_ints))
    assert ring.shard_for_features(feats) == ring.shard_for_features(as_ints)


def test_mixer_golden_identity():
    # Pin the mixer itself (not just its composition with FNV).
    assert mix64(0) == 0
    assert mix64(1) == 0x5692161D100B05E5
    # splitmix64's first output from the golden-ratio seed.
    assert mix64(0x9E3779B97F4A7C15) == 0xE220A8397B1DCDAF
