"""Kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes and random boolean masks; every Pallas kernel
(interpret=True) must agree with ref.py exactly (these are {0,1}/small-int
computations in f32, so equality is exact, no tolerance needed).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.clause_eval import clause_eval, make_literals_kernel
from compile.kernels.class_sum import class_sum_multiclass, class_sum_weighted

# Keep hypothesis example counts modest: interpret-mode pallas is slow.
FAST = settings(max_examples=20, deadline=None)


def rand_bits(rng, *shape):
    return rng.integers(0, 2, size=shape).astype(np.float32)


# ---------------------------------------------------------------- literals


@given(st.integers(1, 8), st.integers(1, 24), st.integers(0, 2**32 - 1))
@FAST
def test_make_literals_matches_ref(b, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_bits(rng, b, f))
    got = make_literals_kernel(x)
    want = ref.make_literals(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_literals_interleaved_order():
    # literal[2i] = x_i, literal[2i+1] = !x_i  (Algorithm 2)
    x = jnp.asarray([[1.0, 0.0, 1.0]])
    lits = np.asarray(make_literals_kernel(x))
    np.testing.assert_array_equal(lits, [[1, 0, 0, 1, 1, 0]])


# ---------------------------------------------------------------- clauses


@given(
    st.integers(1, 6),     # batch
    st.integers(1, 12),    # features
    st.integers(1, 40),    # clauses (crosses no tile boundary: padding path)
    st.integers(0, 2**32 - 1),
)
@FAST
def test_clause_eval_matches_ref(b, f, nc, seed):
    rng = np.random.default_rng(seed)
    lits = jnp.asarray(rand_bits(rng, b, 2 * f))
    inc = jnp.asarray(rand_bits(rng, nc, 2 * f))
    got = clause_eval(lits, inc)
    want = ref.clause_outputs(lits, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_eval_crosses_tile_boundary():
    # NC > CLAUSE_TILE exercises the multi-tile grid path.
    rng = np.random.default_rng(7)
    lits = jnp.asarray(rand_bits(rng, 3, 8))
    inc = jnp.asarray(rand_bits(rng, 300, 8))
    got = clause_eval(lits, inc, clause_tile=128)
    want = ref.clause_outputs(lits, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_eval_small_tile():
    rng = np.random.default_rng(8)
    lits = jnp.asarray(rand_bits(rng, 2, 6))
    inc = jnp.asarray(rand_bits(rng, 10, 6))
    got = clause_eval(lits, inc, clause_tile=4)  # 3 tiles, padded last
    want = ref.clause_outputs(lits, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_empty_clause_outputs_zero():
    # Inference convention: clauses with no includes output 0.
    lits = jnp.asarray([[1.0, 0.0, 1.0, 0.0]])
    inc = jnp.zeros((3, 4), jnp.float32)
    out = np.asarray(clause_eval(lits, inc))
    np.testing.assert_array_equal(out, np.zeros((1, 3)))


def test_full_include_requires_all_literals():
    # A clause including x0 and !x0 can never fire on boolean input.
    lits = ref.make_literals(jnp.asarray([[1.0], [0.0]]))
    inc = jnp.ones((1, 2), jnp.float32)
    out = np.asarray(clause_eval(lits, inc))
    np.testing.assert_array_equal(out, np.zeros((2, 1)))


def test_tautology_free_single_literal_clause():
    # include only x0: fires exactly when x0 = 1.
    lits = ref.make_literals(jnp.asarray([[1.0], [0.0]]))
    inc = jnp.asarray([[1.0, 0.0]])
    out = np.asarray(clause_eval(lits, inc))
    np.testing.assert_array_equal(out, [[1.0], [0.0]])


# --------------------------------------------------------------- class sums


@given(
    st.integers(1, 6),     # batch
    st.integers(1, 30),    # clauses
    st.integers(2, 8),     # classes
    st.integers(0, 2**32 - 1),
)
@FAST
def test_class_sum_weighted_matches_ref(b, c, k, seed):
    rng = np.random.default_rng(seed)
    cl = jnp.asarray(rand_bits(rng, b, c))
    w = jnp.asarray(rng.integers(-8, 9, size=(k, c)).astype(np.float32))
    got = class_sum_weighted(cl, w)
    want = ref.class_sums_cotm(cl, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    st.integers(1, 6),     # batch
    st.integers(1, 10),    # clauses per class
    st.integers(2, 6),     # classes
    st.integers(0, 2**32 - 1),
)
@FAST
def test_class_sum_multiclass_matches_ref(b, c, k, seed):
    rng = np.random.default_rng(seed)
    cl = jnp.asarray(rand_bits(rng, b, k * c))
    got = class_sum_multiclass(cl, num_classes=k)
    want = ref.class_sums_multiclass(cl, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_multiclass_polarity_alternation():
    # One class, clauses [1, 1]: +1 - 1 = 0; clauses [1, 0]: +1.
    cl = jnp.asarray([[1.0, 1.0], [1.0, 0.0]])
    got = np.asarray(class_sum_multiclass(cl, num_classes=1))
    np.testing.assert_array_equal(got, [[0.0], [1.0]])


def test_weighted_sum_signed_weights():
    # CoTM signed weights: clause fires against class 0, for class 1.
    cl = jnp.asarray([[1.0]])
    w = jnp.asarray([[-3.0], [5.0]])
    got = np.asarray(class_sum_weighted(cl, w))
    np.testing.assert_array_equal(got, [[-3.0, 5.0]])
