//! Known-bad R3: the slot is taken and never given back — capacity
//! leaks until restart.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn submit(in_flight: &AtomicU64) {
    in_flight.fetch_add(1, Ordering::SeqCst);
}
