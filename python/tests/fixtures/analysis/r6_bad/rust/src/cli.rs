pub const USAGE: &str = "\
tmtd serve --engine <alpha-backend|beta-backend>

serve.toml knobs, all under [coordinator]:
  shards  worker shards in the ring
";
