fn cmd_selfcheck() {
    println!("backend {} ok", "alpha-backend");
}
