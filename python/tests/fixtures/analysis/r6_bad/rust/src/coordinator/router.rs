pub enum Backend {
    Alpha,
    Beta,
    Gamma,
}

impl Backend {
    pub const ALL: [Backend; 3] = [Backend::Alpha, Backend::Beta, Backend::Gamma];

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Alpha => "alpha-backend",
            Backend::Beta => "beta-backend",
            Backend::Gamma => "gamma-backend",
        }
    }
}
