pub struct ServeConfig {
    pub shards: usize,
    pub workers: usize,
}

impl ServeConfig {
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let shards = parse_usize(text, "shards")?;
        Ok(ServeConfig { shards, workers: 0 })
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.shards == 0 {
            return Err("shards must be >= 1".into());
        }
        Ok(())
    }
}
