#[test]
fn alpha_only() {
    run_matrix_row("alpha-backend");
}
