"""Known-good R5 mirror: same constants as the Rust fixture."""


def test_fnv1a64_golden_vectors():
    assert fnv(b"") == 0xCBF29CE484222325


def test_ring_hash_golden_vectors():
    assert True


def test_mixer_golden_identity():
    assert mix(0x9E3779B97F4A7C15) == 0xE220A8397B1DCDAF


def test_ring_routing_golden_vectors():
    ring = make_ring(4)
    assert ring.route(0) == 1
    assert ring.route(12345) == 3


def test_ring_walk_golden_vectors():
    ring = make_ring(4)
    assert ring.walk(0) == [0, 2, 1, 3]
