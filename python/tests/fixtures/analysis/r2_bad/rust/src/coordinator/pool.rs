//! Known-bad R2: the spawned closure can panic with nothing catching
//! the unwind — the worker dies and its slot leaks.
pub fn start_worker(jobs: Vec<fn()>) {
    std::thread::spawn(move || {
        for job in jobs {
            job();
        }
    });
}
