//! Known-good R2: both spawn shapes reach containment — one directly,
//! one transitively through a same-file fn.
use std::panic::{catch_unwind, AssertUnwindSafe};

fn run_flush(job: fn()) {
    let _ = catch_unwind(AssertUnwindSafe(job));
}

pub fn start_batcher() {
    std::thread::Builder::new()
        .name("flush".into())
        .spawn(move || loop {
            run_flush(|| {});
        })
        .ok();
}

pub fn start_worker(job: fn()) {
    std::thread::spawn(move || {
        let _ = catch_unwind(AssertUnwindSafe(job));
    });
}
