//! Known-good r9 fixture: Relaxed vote traffic on the hot path, one
//! Acquire at the partition join — the documented snapshot contract.

use std::sync::atomic::{AtomicI32, Ordering};

fn publish_and_read(votes: &[AtomicI32], class: usize, contrib: i32) -> i32 {
    votes[class].fetch_add(contrib, Ordering::Relaxed);
    votes[class].load(Ordering::Relaxed)
}

fn join_votes(votes: &[AtomicI32]) -> i32 {
    votes.iter().map(|v| v.load(Ordering::Acquire)).sum()
}
