//! Known-bad R4: unsafe outside tm/simd.rs.
pub fn read_word(p: *const u64) -> u64 {
    unsafe { *p }
}
