//! Known-bad R1: bare unwrap/expect on lock() — poisoning cascades.
use std::sync::Mutex;

pub fn record(ring: &Mutex<Vec<f64>>, x: f64) {
    ring.lock().unwrap().push(x);
}

pub fn render(ring: &Mutex<Vec<f64>>) -> usize {
    ring.lock().expect("ring poisoned").len()
}
