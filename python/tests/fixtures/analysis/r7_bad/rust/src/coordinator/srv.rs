pub fn first(v: &[u64]) -> u64 {
    v.first().copied().unwrap()
}
