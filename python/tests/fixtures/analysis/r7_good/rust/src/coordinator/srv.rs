//! Known-good R7: one pinned slice-index, nothing else.
pub fn first(v: &[u64]) -> u64 {
    v[0]
}
