//! Known-bad r8 fixture: the serving path builds engines straight
//! from raw models, bypassing the compile pass entirely.

pub struct CoordinatorServer {
    bp: BitParallelMulticlass,
    ix: IndexedMulticlass,
}

impl CoordinatorServer {
    pub fn new(cfg: &ServeConfig, model: &MultiClassTmModel) -> Result<Self> {
        let bp = BitParallelMulticlass::from_model(model)?;
        let ix = IndexedMulticlass::from_model(model)?;
        let density = ix.density();
        let _ = select_engine(density, cfg.indexed_threshold, cfg.compressed_threshold);
        Ok(CoordinatorServer { bp, ix })
    }
}
