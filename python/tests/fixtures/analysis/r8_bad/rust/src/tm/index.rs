//! Known-bad r8 fixture: from_model rebuilds its own pipeline
//! (mask walk, private density heuristic) instead of delegating to
//! from_compiled.

impl IndexedMulticlass {
    pub fn from_model(model: &MultiClassTmModel) -> Result<IndexedMulticlass> {
        let mut lists = vec![Vec::new(); 2 * model.params.features];
        for (c, mask) in model.masks().enumerate() {
            for (lit, inc) in mask.iter().enumerate() {
                if *inc {
                    lists[lit].push(c);
                }
            }
        }
        Ok(IndexedMulticlass { lists })
    }
}
