//! Known-bad r9 fixture: every way the async trainer's memory-ordering
//! story can rot — SeqCst on the hot path, Acquire outside the join,
//! and a join downgraded to Relaxed (no Acquire anywhere in a join fn).

use std::sync::atomic::{AtomicI32, Ordering};

fn publish_and_read(votes: &[AtomicI32], class: usize, contrib: i32) -> i32 {
    // SeqCst is banned: the tier must tolerate stale snapshots.
    votes[class].fetch_add(contrib, Ordering::SeqCst);
    // Acquire outside a join fn: the hot path must stay Relaxed.
    votes[class].load(Ordering::Acquire)
}

fn join_votes(votes: &[AtomicI32]) -> i32 {
    // Relaxed at the join: the conservation check can miss updates.
    votes.iter().map(|v| v.load(Ordering::Relaxed)).sum()
}
