//! Known-good R3: the increment pairs with a release on every path.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn submit(in_flight: &AtomicU64, cap: u64) -> Result<(), ()> {
    let n = in_flight.fetch_add(1, Ordering::SeqCst);
    if n >= cap {
        in_flight.fetch_sub(1, Ordering::SeqCst);
        return Err(());
    }
    Ok(())
}
