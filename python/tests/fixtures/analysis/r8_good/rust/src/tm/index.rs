//! Known-good r8 fixture: from_model is a thin wrapper over the
//! compile pass; the real constructor consumes the artifact.

impl IndexedMulticlass {
    /// Convenience: compile with the default mode, then build.
    pub fn from_model(model: &MultiClassTmModel) -> Result<IndexedMulticlass> {
        Self::from_compiled(&ModelCompiler::default().compile_multiclass(model)?)
    }

    /// The artifact boundary: build from live clauses only.
    pub fn from_compiled(compiled: &CompiledMulticlass) -> Result<IndexedMulticlass> {
        compiled.validate()?;
        Ok(IndexedMulticlass { classes: compiled.classes.clone() })
    }
}
