//! Known-good r8 fixture: one ModelCompiler run per model, every
//! engine built from the shared compiled artifact.

pub struct CoordinatorServer {
    bp: BitParallelMulticlass,
    ix: IndexedMulticlass,
}

impl CoordinatorServer {
    pub fn new(cfg: &ServeConfig, model: &MultiClassTmModel) -> Result<Self> {
        let compiler = ModelCompiler::new(cfg.compile);
        let compiled = compiler.compile_multiclass(model)?;
        let bp = BitParallelMulticlass::from_compiled(&compiled)?;
        let ix = IndexedMulticlass::from_compiled(&compiled)?;
        let density = compiled.stats.density;
        let _ = select_engine(density, cfg.indexed_threshold, cfg.compressed_threshold);
        Ok(CoordinatorServer { bp, ix })
    }
}

#[cfg(test)]
mod tests {
    // from_model is fine in tests: the convenience wrapper itself
    // routes through the compile pass.
    #[test]
    fn builds() {
        let e = IndexedMulticlass::from_model(&tiny_model()).unwrap();
        assert!(e.density() >= 0.0);
    }
}
