//! Known-good R4: unsafe only as a #[target_feature] kernel plus a
//! dispatch block calling it behind runtime detection.

fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// # Safety
/// Caller must guarantee the host supports AVX2.
#[target_feature(enable = "avx2")]
pub unsafe fn and_any_avx2(acc: &mut [u64]) -> bool {
    acc.iter().any(|&w| w != 0)
}

pub fn and_any(acc: &mut [u64]) -> bool {
    if avx2_available() {
        // SAFETY: detected above.
        unsafe { and_any_avx2(acc) }
    } else {
        acc.iter().any(|&w| w != 0)
    }
}
