//! Known-good R5: the golden constants equal the Python mirror's.
#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a64_golden_vectors() {
        assert_eq!(fnv(b""), 0xCBF2_9CE4_8422_2325u64);
    }

    #[test]
    fn ring_hash_golden_vectors() {
        assert_eq!(mix(0x9E3779B97F4A7C15), 0xE220_A839_7B1D_CDAFu64);
    }

    #[test]
    fn ring_routing_golden_vectors() {
        let ring = ring(4);
        assert_eq!(ring.route(0), 1);
        assert_eq!(ring.route(12345), 3);
    }

    #[test]
    fn ring_walk_golden_vectors() {
        let ring = ring(4);
        assert_eq!(ring.walk(0), vec![0, 2, 1, 3]);
    }
}
