//! Known-good R1: every acquire goes through the poison-tolerant helper.
use std::sync::{Mutex, MutexGuard};

fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

pub fn record(ring: &Mutex<Vec<f64>>, x: f64) {
    lock_unpoisoned(ring).push(x);
}

pub fn drain(ring: &Mutex<Vec<f64>>) -> Vec<f64> {
    // A match-based recovery is also fine — R1 only rejects the bare
    // unwrap/expect forms.
    let guard = match ring.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    guard.clone()
}
