#[test]
fn every_backend_agrees() {
    for b in Backend::ALL.iter() {
        run_matrix_row(b);
    }
}
