fn cmd_selfcheck() {
    for b in Backend::ALL.iter() {
        println!("backend {} ok", b.name());
    }
}
