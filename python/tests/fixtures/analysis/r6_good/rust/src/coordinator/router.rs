//! Known-good R6: a two-backend registry.
pub enum Backend {
    Alpha,
    Beta,
}

impl Backend {
    pub const ALL: [Backend; 2] = [Backend::Alpha, Backend::Beta];

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Alpha => "alpha-backend",
            Backend::Beta => "beta-backend",
        }
    }
}
