//! Known-good R6 USAGE: names every backend and every serve.toml knob.
pub const USAGE: &str = "\
tmtd serve --engine <alpha-backend|beta-backend>

serve.toml knobs, all under [coordinator]:
  shards  worker shards in the ring
";
