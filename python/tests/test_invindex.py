"""Inverted-index counter-sweep mirror vs the Rust engines (tm/index.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. The golden
models, samples and class sums below are asserted *identically* in
``rust/src/tm/index.rs`` (``golden_vectors_match_python_mirror``); both
sides build them from the same closed-form formulas, so if either
implementation drifts, both suites fail.
"""

import random

from invindex import (
    IndexedCotm,
    IndexedMulticlass,
    InvertedIndex,
    ref_cotm_class_sums,
    ref_multiclass_class_sums,
)

# ---------------------------------------------------------------------
# The shared golden scheme (formulas mirrored in index.rs):
#   multiclass: F=9, C=4/class, K=3; include(k,j,l) = (3l+5j+7k)%11 == 0
#   cotm:       F=9, C=6, K=3; include(j,l) = (5l+3j)%7 == 0,
#               weight(k,j) = (j+2k)%7 - 3
#   sample s:   feature i = (i*i + 3*i*s + 2*s) % 7 < 3
# ---------------------------------------------------------------------

F = 9
LITS = 2 * F

GOLDEN_MC_CLAUSES = [
    [[(3 * l + 5 * j + 7 * k) % 11 == 0 for l in range(LITS)] for j in range(4)]
    for k in range(3)
]
GOLDEN_CO_CLAUSES = [
    [(5 * l + 3 * j) % 7 == 0 for l in range(LITS)] for j in range(6)
]
GOLDEN_CO_WEIGHTS = [[(j + 2 * k) % 7 - 3 for j in range(6)] for k in range(3)]


def golden_sample(s):
    return [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(F)]


GOLDEN_MC_SUMS = [
    [1, 0, -1],
    [0, -1, 2],
    [0, -1, 0],
    [0, 0, 0],
    [-1, -1, 1],
    [0, 0, 0],
]
GOLDEN_CO_SUMS = [
    [-2, 0, 2],
    [-6, 0, 6],
    [0, 2, -3],
    [3, 2, -6],
    [-3, -1, 1],
    [3, 2, -6],
]


def test_multiclass_golden_vectors():
    eng = IndexedMulticlass(GOLDEN_MC_CLAUSES)
    for s in range(6):
        x = golden_sample(s)
        assert eng.class_sums(x) == GOLDEN_MC_SUMS[s], s
        # The goldens themselves match the direct reference, so all
        # three tiers (Rust indexed, Rust scalar, this mirror) pin the
        # same semantics.
        assert ref_multiclass_class_sums(GOLDEN_MC_CLAUSES, x) == GOLDEN_MC_SUMS[s], s


def test_cotm_golden_vectors():
    eng = IndexedCotm(GOLDEN_CO_CLAUSES, GOLDEN_CO_WEIGHTS)
    for s in range(6):
        x = golden_sample(s)
        assert eng.class_sums(x) == GOLDEN_CO_SUMS[s], s
        assert (
            ref_cotm_class_sums(GOLDEN_CO_CLAUSES, GOLDEN_CO_WEIGHTS, x)
            == GOLDEN_CO_SUMS[s]
        ), s


def test_hand_worked_multiclass_oracle():
    # The same hand-worked example as rust/src/tm/infer.rs and
    # python/tests/test_model.py: both layers must agree on it.
    clauses = [
        [
            [True, False, False, False],   # class0 clause0 (+): x0
            [False, False, False, True],   # class0 clause1 (-): not x1
        ],
        [
            [False, True, False, False],   # class1 clause0 (+): not x0
            [False, False, True, False],   # class1 clause1 (-): x1
        ],
    ]
    eng = IndexedMulticlass(clauses)
    assert eng.class_sums([True, False]) == [0, 0]
    assert eng.class_sums([True, True]) == [1, -1]


def test_hand_worked_cotm_oracle():
    clauses = [
        [True, False, False, False],   # clause0: x0
        [False, False, True, False],   # clause1: x1
    ]
    weights = [[3, -2], [-1, 4]]
    eng = IndexedCotm(clauses, weights)
    assert eng.class_sums([True, True]) == [1, 3]
    assert eng.class_sums([True, False]) == [3, -1]
    assert eng.class_sums([False, False]) == [0, 0]


def test_empty_clause_never_fires():
    # All-exclude clauses appear in no literal list: counter starts at 0
    # and is never decremented — the "empty clause outputs 0" convention.
    eng = IndexedCotm([[False] * 4, [False] * 4], [[5, 7], [1, 2]])
    assert eng.class_sums([True, True]) == [0, 0]
    assert eng.class_sums([False, False]) == [0, 0]


def test_contradictory_clause_never_fires():
    # x0 AND not-x0 can never be satisfied: only one of the pair is set.
    eng = IndexedCotm([[True, True, False, False]], [[5], [5]])
    for x in ([True, True], [False, False], [True, False]):
        assert eng.class_sums(x) == [0, 0], x


def test_sweep_restores_counters_across_a_batch():
    idx = InvertedIndex(F, [m for cls in GOLDEN_MC_CLAUSES for m in cls])
    baseline = list(idx.required)
    for s in range(6):
        idx.sweep(golden_sample(s))
        assert idx._counts == baseline, s


def test_fired_ids_are_events_not_rescans():
    # A clause fires exactly once, at the instant its last unsatisfied
    # literal is seen — no duplicates even when several of its literals
    # are set.
    idx = InvertedIndex(2, [[True, False, True, False]])  # x0 AND x1
    assert idx.sweep([True, True]) == [0]
    assert idx.sweep([True, False]) == []
    assert idx.sweep([False, True]) == []


def test_density_accounting():
    idx = InvertedIndex(F, GOLDEN_CO_CLAUSES)
    included = sum(sum(m) for m in GOLDEN_CO_CLAUSES)
    assert idx.postings() == included
    assert abs(idx.density() - included / (6 * LITS)) < 1e-12
    assert InvertedIndex(2, [[False] * 4]).density() == 0.0


def _random_masks(rng, n, lits, density):
    return [[rng.random() < density for _ in range(lits)] for _ in range(n)]


def test_randomized_differential_multiclass():
    # 300 random models spanning all-exclude to dense clauses: the
    # counter sweep must equal the direct evaluator sample-for-sample.
    rng = random.Random(0x7E57CA5E)
    for case in range(300):
        f = rng.randint(1, 24)
        c = 2 * rng.randint(1, 4)
        k = rng.randint(2, 4)
        density = rng.choice([0.0, 0.05, 0.15, 0.4, 0.8])
        clauses = [_random_masks(rng, c, 2 * f, density) for _ in range(k)]
        eng = IndexedMulticlass(clauses)
        for _ in range(4):
            x = [rng.random() < 0.5 for _ in range(f)]
            assert eng.class_sums(x) == ref_multiclass_class_sums(clauses, x), (
                case, f, c, k, density,
            )


def test_randomized_differential_cotm():
    rng = random.Random(0xC07A)
    for case in range(300):
        f = rng.randint(1, 24)
        c = rng.randint(1, 8)
        k = rng.randint(2, 4)
        density = rng.choice([0.0, 0.05, 0.15, 0.4, 0.8])
        clauses = _random_masks(rng, c, 2 * f, density)
        weights = [[rng.randint(-7, 7) for _ in range(c)] for _ in range(k)]
        eng = IndexedCotm(clauses, weights)
        for _ in range(4):
            x = [rng.random() < 0.5 for _ in range(f)]
            assert eng.class_sums(x) == ref_cotm_class_sums(clauses, weights, x), (
                case, f, c, k, density,
            )
