"""L2 model vs oracle, plus end-to-end functional sanity on TM semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

FAST = settings(max_examples=15, deadline=None)


def rand_bits(rng, *shape):
    return rng.integers(0, 2, size=shape).astype(np.float32)


@given(st.integers(1, 4), st.integers(1, 8), st.integers(1, 6),
       st.integers(2, 4), st.integers(0, 2**32 - 1))
@FAST
def test_multiclass_model_matches_ref(b, f, c, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_bits(rng, b, f))
    inc = jnp.asarray(rand_bits(rng, k, c, 2 * f))
    (got,) = model.multiclass_tm_infer(x, inc)
    want = ref.multiclass_tm_infer(x, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 4), st.integers(1, 8), st.integers(1, 10),
       st.integers(2, 4), st.integers(0, 2**32 - 1))
@FAST
def test_cotm_model_matches_ref(b, f, c, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_bits(rng, b, f))
    inc = jnp.asarray(rand_bits(rng, c, 2 * f))
    w = jnp.asarray(rng.integers(-7, 8, size=(k, c)).astype(np.float32))
    (got,) = model.cotm_infer(x, inc, w)
    want = ref.cotm_infer(x, inc, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clause_only_matches_ref():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rand_bits(rng, 5, 16))
    inc = jnp.asarray(rand_bits(rng, 12, 32))
    (got,) = model.clause_only(x, inc)
    want = ref.clause_outputs(ref.make_literals(x), inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hand_worked_multiclass_example():
    """2 features, 2 classes, 2 clauses/class, worked by hand.

    Class 0: clause0 (+) includes x0;      clause1 (−) includes !x1.
    Class 1: clause0 (+) includes !x0;     clause1 (−) includes x1.
    Input x = [1, 0]:
      class0 = +1 (x0=1) − 1 (!x1=1)  = 0
      class1 = +0 (!x0=0) − 0 (x1=0)  = 0
    Input x = [1, 1]:
      class0 = +1 − 0 = 1 ; class1 = 0 − 1 = −1  -> predicts class 0
    """
    inc = np.zeros((2, 2, 4), np.float32)
    inc[0, 0, 0] = 1  # class0 clause0: x0
    inc[0, 1, 3] = 1  # class0 clause1: !x1
    inc[1, 0, 1] = 1  # class1 clause0: !x0
    inc[1, 1, 2] = 1  # class1 clause1: x1
    x = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])
    (sums,) = model.multiclass_tm_infer(x, jnp.asarray(inc))
    np.testing.assert_array_equal(np.asarray(sums), [[0.0, 0.0], [1.0, -1.0]])
    assert ref.predict(sums)[1] == 0


def test_hand_worked_cotm_example():
    """Shared clauses with signed weights (Eq. 2), worked by hand."""
    inc = np.zeros((2, 4), np.float32)
    inc[0, 0] = 1  # clause0: x0
    inc[1, 2] = 1  # clause1: x1
    w = jnp.asarray([[3.0, -2.0], [-1.0, 4.0]])
    x = jnp.asarray([[1.0, 1.0], [1.0, 0.0], [0.0, 0.0]])
    (sums,) = model.cotm_infer(x, jnp.asarray(inc), w)
    # x=[1,1]: clauses [1,1] -> class sums [3-2, -1+4] = [1, 3]
    # x=[1,0]: clauses [1,0] -> [3, -1]
    # x=[0,0]: clauses [0,0] -> [0, 0]
    np.testing.assert_array_equal(
        np.asarray(sums), [[1.0, 3.0], [3.0, -1.0], [0.0, 0.0]]
    )
