"""Packed-evaluation trainer mirror vs the Rust trainers (tm/train.rs,
tm/cotm_train.rs, tm/trainer_engine.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. Three layers
of pinning, mirroring the hashring/invindex arrangement:

1. RNG-stream goldens: the SplitMix64 mirror must produce the exact
   values the Rust ``util/rng.rs`` produces (asserted identically in
   ``trainer_engine.rs::splitmix_stream_matches_python_mirror``).
2. Trained-model goldens: tiny closed-form datasets trained for a few
   epochs; the exported include masks / weights are hard-coded here and
   asserted *identically* in ``trainer_engine.rs`` — if either
   language's trainer drifts, both suites fail.
3. The PR's headline invariant, validated end-to-end in Python: for the
   same seed, the packed-evaluation trainer is **bit-identical** to the
   reference per-literal trainer, across word-boundary feature widths,
   for both the multi-class TM and the CoTM.
"""

import random

from packedtrain import (
    ClauseState,
    CoTmTrainer,
    MultiClassTrainer,
    SplitMix64,
    TmParams,
    make_literals,
    pack_bools,
    pack_literals,
    type_i,
    type_ii,
)

# Literal-space word boundaries: F=32 is exactly one 64-literal word,
# 33 spills into a tail word; 63/64/65 are the two-word boundary.
BOUNDARY_WIDTHS = [31, 32, 33, 63, 64, 65]


def synth(f, n_samples, classes):
    """Closed-form dataset shared verbatim with the Rust unit tests."""
    feats = [
        [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(f)]
        for s in range(n_samples)
    ]
    labels = [s % classes for s in range(n_samples)]
    return feats, labels


def bits(mask):
    return "".join("1" if b else "0" for b in mask)


# ---------------------------------------------------------------------
# 1. RNG stream goldens (asserted identically in trainer_engine.rs).
# ---------------------------------------------------------------------

def test_splitmix_stream_goldens():
    r = SplitMix64(42)
    assert [r.next_u64() for _ in range(4)] == [
        0xBDD732262FEB6E95,
        0x28EFE333B266F103,
        0x47526757130F9F52,
        0x581CE1FF0E4AE394,
    ]
    r = SplitMix64(7)
    assert (
        "".join("1" if r.chance(1.0 / 3.0) else "0" for _ in range(32))
        == "01000101101000000100010000100001"
    )
    r = SplitMix64(9)
    assert [r.index(5) for _ in range(12)] == [3, 3, 1, 3, 1, 0, 3, 4, 1, 3, 2, 1]
    xs = list(range(8))
    r = SplitMix64(3)
    r.shuffle(xs)
    assert xs == [2, 5, 1, 6, 7, 3, 4, 0]


# ---------------------------------------------------------------------
# 2. Trained-model goldens (shared verbatim with trainer_engine.rs).
#    multiclass: F=5 C=4 K=2 N=8 T=3 s=3.0, 12 samples, 3 epochs, seed 42
#    cotm:       F=5 C=5 K=3 N=8 T=3 s=3.0 wmax=3, 12 samples, 3 epochs,
#                seed 43
# ---------------------------------------------------------------------

GOLDEN_MC_MASKS = [
    ["0000000001", "0001000001", "0000100001", "0000000001"],  # class 0
    ["0010000000", "0000000001", "1010000001", "1000000100"],  # class 1
]
GOLDEN_CO_MASKS = [
    "0000000110",
    "1010011000",
    "0000000001",
    "1010001010",
    "0100010010",
]
GOLDEN_CO_WEIGHTS = [
    [-1, 1, 0, -1, 0],
    [-1, 2, 0, 2, -2],
    [0, -3, 0, 0, 1],
]


def test_multiclass_trained_golden_model():
    feats, labels = synth(5, 12, 2)
    for engine in ("reference", "packed"):
        tr = MultiClassTrainer(TmParams(5, 4, 2, 8, 3, 3.0), 42, engine)
        model = tr.train(feats, labels, 3)
        got = [[bits(mask) for mask in cls] for cls in model]
        assert got == GOLDEN_MC_MASKS, engine
        assert tr.coherent() and tr.states_in_bounds()


def test_cotm_trained_golden_model():
    feats, labels = synth(5, 12, 3)
    for engine in ("reference", "packed"):
        tr = CoTmTrainer(TmParams(5, 5, 3, 8, 3, 3.0, 3), 43, engine)
        masks, weights = tr.train(feats, labels, 3)
        assert [bits(m) for m in masks] == GOLDEN_CO_MASKS, engine
        assert weights == GOLDEN_CO_WEIGHTS, engine
        assert tr.coherent() and tr.states_in_bounds()


# ---------------------------------------------------------------------
# 3. The headline invariant: packed == reference, bit for bit, for the
#    same seed — including the RNG end state (stream never diverges).
# ---------------------------------------------------------------------

def test_multiclass_packed_bit_identical_across_boundary_widths():
    for f in BOUNDARY_WIDTHS:
        feats, labels = synth(f, 30, 3)
        p = TmParams(f, 8, 3, 32, 4, 3.0)
        ref = MultiClassTrainer(p, 99, "reference")
        packed = MultiClassTrainer(p, 99, "packed")
        assert ref.train(feats, labels, 3) == packed.train(feats, labels, 3), f
        assert ref.rng.state == packed.rng.state, f
        assert packed.coherent(), f


def test_cotm_packed_bit_identical_across_boundary_widths():
    for f in BOUNDARY_WIDTHS:
        feats, labels = synth(f, 30, 3)
        p = TmParams(f, 7, 3, 32, 4, 3.0, 5)
        ref = CoTmTrainer(p, 77, "reference")
        packed = CoTmTrainer(p, 77, "packed")
        assert ref.train(feats, labels, 3) == packed.train(feats, labels, 3), f
        assert ref.rng.state == packed.rng.state, f
        assert packed.coherent(), f


def test_randomized_same_seed_equality():
    # Random shapes/seeds/epochs: the invariant is structural, not a
    # property of any particular configuration.
    rnd = random.Random(1234)
    for case in range(30):
        f = rnd.randrange(1, 40)
        classes = rnd.randrange(2, 5)
        clauses = 2 * rnd.randrange(1, 5)
        seed = rnd.getrandbits(64)
        epochs = rnd.randrange(1, 4)
        feats = [
            [rnd.random() < 0.5 for _ in range(f)] for _ in range(20)
        ]
        labels = [rnd.randrange(classes) for _ in range(20)]
        p = TmParams(f, clauses, classes, 16, 3, 3.0, 4)
        a = MultiClassTrainer(p, seed, "reference").train(feats, labels, epochs)
        b = MultiClassTrainer(p, seed, "packed").train(feats, labels, epochs)
        assert a == b, case
        ca = CoTmTrainer(p, seed, "reference").train(feats, labels, epochs)
        cb = CoTmTrainer(p, seed, "packed").train(feats, labels, epochs)
        assert ca == cb, case


# ---------------------------------------------------------------------
# Clause-state unit level: randomized differential cases against the
# direct per-literal evaluator, and incremental-mask coherence under
# arbitrary TA-state walks.
# ---------------------------------------------------------------------

def test_incremental_mask_matches_recompute_under_random_walks():
    rnd = random.Random(99)
    for _ in range(50):
        lits = rnd.randrange(1, 140)
        n = rnd.randrange(1, 64)
        cs = ClauseState(
            [rnd.randrange(1, 2 * n + 1) for _ in range(lits)], n
        )
        assert cs.coherent(n)
        for _ in range(200):
            l = rnd.randrange(lits)
            cs.set_ta(l, rnd.randrange(1, 2 * n + 1), n)
        assert cs.coherent(n)
        assert cs.include_words == pack_bools([st > n for st in cs.states])


def test_packed_firing_matches_per_literal_firing():
    # Training-time semantics on both paths, including the empty-clause
    # -fires convention (all-exclude -> all-zero words -> vacuous AND).
    rnd = random.Random(7)
    for _ in range(200):
        f = rnd.randrange(1, 80)
        n = 8
        states = [
            n if rnd.random() < 0.7 else rnd.randrange(1, 2 * n + 1)
            for _ in range(2 * f)
        ]
        cs = ClauseState(states, n)
        x = [rnd.random() < 0.5 for _ in range(f)]
        lits = make_literals(x)
        words = pack_literals(x)
        assert cs.fires_packed(words) == cs.fires_reference(lits, n)


def test_empty_clause_fires_at_training_time():
    n = 8
    cs = ClauseState([n] * 10, n)  # all-exclude
    x = [True, False, True, False, True]
    assert cs.included == 0
    assert cs.fires_packed(pack_literals(x))
    assert cs.fires_reference(make_literals(x), n)


def test_feedback_keeps_states_in_bounds_and_mask_coherent():
    rnd = random.Random(5)
    rng = SplitMix64(11)
    n, f = 4, 10
    cs = ClauseState.init(2 * f, n, rng)
    for _ in range(300):
        x = [rnd.random() < 0.5 for _ in range(f)]
        lits = make_literals(x)
        if rnd.random() < 0.5:
            type_i(cs, lits, rnd.random() < 0.5, n, 3.0, rng)
        else:
            type_ii(cs, lits, n)
        assert all(1 <= st <= 2 * n for st in cs.states)
        assert cs.coherent(n)
