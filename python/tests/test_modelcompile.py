"""Model-compile pass mirror vs the Rust compiler (tm/compile.rs).

Plain pytest (no hypothesis, no JAX) so it runs on every CI image —
including toolchain-less ones where the Rust suite cannot. The golden
models, calibration batches, pruned counts, stats, plans and reordered
source orders below are asserted *identically* in
``rust/src/tm/compile.rs`` (``golden_models_compile_to_pinned_stats_and_orders``
and friends); both sides build them from the same closed-form formulas,
so if either implementation drifts, both suites fail.
"""

import random

from compressed import CompressedModel, select_engine
from invindex import (
    InvertedIndex,
    ref_cotm_class_sums,
    ref_multiclass_class_sums,
)
from modelcompile import (
    HIST_BUCKETS,
    CompileStats,
    ModelCompiler,
    dead_reason,
    plan_for_mask,
    prefers_lane_sweep,
)

# ---------------------------------------------------------------------
# The shared golden scheme (formulas mirrored in compile.rs — the same
# models the invindex/compressed mirrors pin):
#   multiclass: F=9, C=4/class, K=3; include(k,j,l) = (3l+5j+7k)%11 == 0
#   cotm:       F=9, C=6, K=3; include(j,l) = (5l+3j)%7 == 0,
#               weight(k,j) = (j+2k)%7 - 3
#   sample s:   feature i = (i*i + 3*i*s + 2*s) % 7 < 3
#   calibration: samples 0..5
# ---------------------------------------------------------------------

F = 9
LITS = 2 * F

GOLDEN_MC_CLAUSES = [
    [[(3 * l + 5 * j + 7 * k) % 11 == 0 for l in range(LITS)] for j in range(4)]
    for k in range(3)
]
GOLDEN_CO_CLAUSES = [
    [(5 * l + 3 * j) % 7 == 0 for l in range(LITS)] for j in range(6)
]
GOLDEN_CO_WEIGHTS = [[(j + 2 * k) % 7 - 3 for j in range(6)] for k in range(3)]


def golden_sample(s):
    return [(i * i + 3 * i * s + 2 * s) % 7 < 3 for i in range(F)]


GOLDEN_CALIBRATION = [golden_sample(s) for s in range(6)]

# Pinned in compile.rs: full-mode execution orders (source ids) under
# the golden calibration batch.
GOLDEN_MC_ORDERS = [[1, 2, 0, 3], [1, 0, 3, 2], [0, 2, 3, 1]]
GOLDEN_CO_ORDER = [3, 0, 1, 4, 5, 2]


def mask_of(literals, lits):
    m = [False] * literals
    for l in lits:
        m[l] = True
    return m


# The hand-worked dead-clause models (mirrored in compile.rs):
# multiclass F=3, K=2, C=4; cotm F=3, C=5, K=2.
def dead_multiclass():
    cls0 = [mask_of(6, [1, 4]), mask_of(6, []), mask_of(6, [2, 3]), mask_of(6, [0])]
    cls1 = [mask_of(6, [0, 1]), mask_of(6, [5]), mask_of(6, [0, 2]), mask_of(6, [])]
    return [cls0, cls1]


def dead_cotm():
    clauses = [
        mask_of(6, [4]),
        mask_of(6, []),
        mask_of(6, [0, 4]),
        mask_of(6, [2, 3]),
        mask_of(6, [1]),
    ]
    weights = [[1, 3, -1, 5, 0], [-2, 3, 2, 5, 1]]
    return clauses, weights


def all_combos():
    """All 8 feature combinations of F=3 — the hand-worked calibration."""
    return [[(bits >> i) & 1 == 1 for i in range(3)] for bits in range(8)]


def test_dead_reason_classifies_the_three_kinds():
    assert dead_reason(mask_of(6, [])) == "all_exclude"
    assert dead_reason(mask_of(6, [2, 3])) == "contradictory"
    assert dead_reason(mask_of(6, [0, 2])) is None
    # A pair split across features is not a contradiction.
    assert dead_reason(mask_of(6, [1, 2])) is None
    # Zero-width masks are the all-exclude degenerate case.
    assert dead_reason([]) == "all_exclude"


def test_plan_rule_matches_the_packed_heuristic_boundaries():
    # Pinned identically in compile.rs: lane-sweep iff nonzero_words >=
    # 8 and 2*nonzero >= words.
    assert not prefers_lane_sweep(7, 14)
    assert prefers_lane_sweep(8, 16)
    assert not prefers_lane_sweep(8, 17)
    assert prefers_lane_sweep(16, 16)
    assert not prefers_lane_sweep(0, 0)
    assert plan_for_mask(mask_of(6, [0])) == "skip"
    assert plan_for_mask(mask_of(1024, list(range(0, 1024, 64)))) == "sweep"
    assert plan_for_mask(mask_of(1024, list(range(0, 1024, 128)))) == "sweep"
    assert plan_for_mask(mask_of(1024, list(range(0, 1024, 256)))) == "skip"
    assert plan_for_mask(mask_of(896, list(range(0, 896, 128)))) == "skip"
    assert plan_for_mask(mask_of(640, list(range(0, 640, 64)))) == "sweep"


def test_dead_multiclass_prunes_exactly_and_keeps_explicit_polarity():
    c = ModelCompiler("prune").compile_multiclass(dead_multiclass())
    # Pinned by the Rust suite: stats of the hand-worked model.
    assert c.stats.total_clauses == 8
    assert c.stats.dead_all_exclude == 2
    assert c.stats.dead_contradictory == 2
    assert c.stats.live_clauses == 4
    assert c.stats.postings == 6
    assert abs(c.stats.density - 0.25) < 1e-12
    assert c.stats.length_histogram == [0, 2, 2, 0, 0, 0, 0, 0]
    assert c.stats.skip_list_clauses == 4
    assert c.stats.lane_sweep_clauses == 0
    assert c.source_orders() == [[0, 3], [1, 2]]
    assert c.polarities == [[1, -1], [-1, 1]]


def test_full_reorder_is_deterministic_and_pinned():
    # Hand-worked fire counts over all 8 F=3 combos:
    # class 0: {1,4} fires 2, {0} fires 4 -> order [3, 0].
    # class 1: {5} fires 4, {0,2} fires 2 -> order [1, 2].
    c = (
        ModelCompiler("full")
        .with_calibration(all_combos())
        .compile_multiclass(dead_multiclass())
    )
    assert c.source_orders() == [[3, 0], [1, 2]]
    assert c.polarities == [[-1, 1], [-1, 1]]

    clauses, weights = dead_cotm()
    co = (
        ModelCompiler("full")
        .with_calibration(all_combos())
        .compile_cotm(clauses, weights)
    )
    # CoTM fires {4}:4, {0,4}:2, {1}:4 -> order [0, 4, 2]; weight
    # columns permuted in lockstep.
    assert co.source_order() == [0, 4, 2]
    assert co.weight_cols == [[1, -2], [0, 1], [-1, 2]]
    assert co.stats.total_clauses == 5
    assert co.stats.dead_all_exclude == 1
    assert co.stats.dead_contradictory == 1
    assert co.stats.live_clauses == 3
    assert co.stats.postings == 4
    assert abs(co.stats.density - 4 / 18) < 1e-12
    assert co.stats.length_histogram == [0, 2, 1, 0, 0, 0, 0, 0]


def test_golden_models_compile_to_pinned_stats_and_orders():
    mc = (
        ModelCompiler("full")
        .with_calibration(GOLDEN_CALIBRATION)
        .compile_multiclass(GOLDEN_MC_CLAUSES)
    )
    assert mc.stats.total_clauses == 12
    assert mc.stats.live_clauses == 12
    assert mc.stats.postings == 21
    assert abs(mc.stats.density - 21 / (12 * 18)) < 1e-12
    assert mc.stats.length_histogram == [12, 0, 0, 0, 0, 0, 0, 0]
    assert mc.source_orders() == GOLDEN_MC_ORDERS

    co = (
        ModelCompiler("full")
        .with_calibration(GOLDEN_CALIBRATION)
        .compile_cotm(GOLDEN_CO_CLAUSES, GOLDEN_CO_WEIGHTS)
    )
    assert co.stats.postings == 15
    assert abs(co.stats.density - 15 / (6 * 18)) < 1e-12
    assert co.stats.length_histogram == [3, 3, 0, 0, 0, 0, 0, 0]
    assert co.source_order() == GOLDEN_CO_ORDER


def test_compiled_sums_are_bit_identical_in_every_mode():
    # The exactness bar: the compiled artifact's direct walk matches the
    # reference evaluator on every F=3 input, whatever mode ran.
    mc_model = dead_multiclass()
    co_clauses, co_weights = dead_cotm()
    for mode in ("off", "prune", "full"):
        compiler = ModelCompiler(mode).with_calibration(all_combos())
        mc = compiler.compile_multiclass(mc_model)
        co = compiler.compile_cotm(co_clauses, co_weights)
        for x in all_combos():
            assert mc.class_sums(x) == ref_multiclass_class_sums(mc_model, x)
            assert co.class_sums(x) == ref_cotm_class_sums(
                co_clauses, co_weights, x
            )


def test_compiled_artifacts_drive_the_serving_engines_exactly():
    # The from_compiled construction, mirrored at mask level: build the
    # inverted-index and compressed engines over the *pruned, reordered*
    # clause list and vote with the artifact's explicit
    # polarities/weight columns — sums must stay bit-identical.
    mc_model = dead_multiclass()
    mc = (
        ModelCompiler("full")
        .with_calibration(all_combos())
        .compile_multiclass(mc_model)
    )
    flat_masks = [cc.mask for cls in mc.classes for cc in cls]
    votes = [
        (k, pol)
        for k, (cls, pols) in enumerate(zip(mc.classes, mc.polarities))
        for _, pol in zip(cls, pols)
    ]
    index = InvertedIndex(3, flat_masks)
    comp = CompressedModel(3, flat_masks)
    for x in all_combos():
        want = ref_multiclass_class_sums(mc_model, x)
        for fired in (index.sweep(x), comp.sweep(x)):
            sums = [0, 0]
            for cid in fired:
                k, pol = votes[cid]
                sums[k] += pol
            assert sums == want, x


def test_stats_are_mode_independent_and_off_keeps_model_order():
    m = dead_multiclass()
    off = ModelCompiler("off").compile_multiclass(m)
    pruned = ModelCompiler("prune").compile_multiclass(m)
    assert off.source_orders() == [[0, 1, 2, 3], [0, 1, 2, 3]]
    for field in (
        "total_clauses",
        "live_clauses",
        "dead_all_exclude",
        "dead_contradictory",
        "postings",
        "density",
        "length_histogram",
    ):
        assert getattr(off.stats, field) == getattr(pruned.stats, field), field
    # Full without a calibration batch keeps the prune order.
    full = ModelCompiler("full").compile_multiclass(m)
    assert full.source_orders() == pruned.source_orders()


def test_all_dead_model_compiles_and_sums_to_zero():
    # Adversarial: every clause dead. No crash, zero live clauses,
    # density 0.0, all-zero sums in every mode.
    clauses = [
        [mask_of(6, []), mask_of(6, [0, 1]), mask_of(6, [4, 5]), mask_of(6, [])]
        for _ in range(3)
    ]
    for mode in ("off", "prune", "full"):
        c = (
            ModelCompiler(mode)
            .with_calibration(all_combos())
            .compile_multiclass(clauses)
        )
        assert c.stats.live_clauses == 0
        assert c.stats.density == 0.0
        for x in all_combos():
            assert c.class_sums(x) == [0, 0, 0]
    co = ModelCompiler("prune").compile_cotm(
        [mask_of(6, []), mask_of(6, [2, 3])], [[5, -5], [1, 1]]
    )
    assert co.clauses == []
    assert co.stats.density == 0.0
    for x in all_combos():
        assert co.class_sums(x) == [0, 0]


def test_duplicate_clauses_keep_independent_votes():
    # Adversarial: identical clauses everywhere. Dedup is NOT part of
    # the contract; ties in fire count fall back to source order.
    template = mask_of(6, [0, 2])
    clauses = [[list(template) for _ in range(4)] for _ in range(2)]
    c = (
        ModelCompiler("full")
        .with_calibration(all_combos())
        .compile_multiclass(clauses)
    )
    assert c.source_orders() == [[0, 1, 2, 3], [0, 1, 2, 3]]
    for x in all_combos():
        assert c.class_sums(x) == ref_multiclass_class_sums(clauses, x)


def test_minimum_shape_models_compile_exactly():
    # Adversarial: the smallest shapes — one clause pair per class
    # (multiclass), a single shared clause (CoTM).
    clauses = [[mask_of(2, [0]), mask_of(2, [1])] for _ in range(2)]
    for mode in ("off", "prune", "full"):
        c = (
            ModelCompiler(mode)
            .with_calibration([[True], [False]])
            .compile_multiclass(clauses)
        )
        for x in ([True], [False]):
            assert c.class_sums(x) == ref_multiclass_class_sums(clauses, x)
    co = ModelCompiler("full").with_calibration([[True], [False]]).compile_cotm(
        [mask_of(2, [0])], [[3], [-2]]
    )
    for x in ([True], [False]):
        assert co.class_sums(x) == ref_cotm_class_sums(
            [mask_of(2, [0])], [[3], [-2]], x
        )


def test_reorder_is_output_invariant_under_random_calibration():
    # Any calibration batch may permute the layout; none may move the
    # sums.
    rng = random.Random(0xC0311E)
    for _ in range(20):
        f = rng.randrange(2, 12)
        c = 2 * rng.randrange(1, 4)
        k = rng.randrange(2, 4)
        clauses = [
            [[rng.random() < 0.3 for _ in range(2 * f)] for _ in range(c)]
            for _ in range(k)
        ]
        samples = [[rng.random() < 0.5 for _ in range(f)] for _ in range(8)]
        calib = [
            [rng.random() < 0.5 for _ in range(f)]
            for _ in range(rng.randrange(1, 20))
        ]
        compiled = (
            ModelCompiler("full").with_calibration(calib).compile_multiclass(clauses)
        )
        for x in samples:
            assert compiled.class_sums(x) == ref_multiclass_class_sums(clauses, x)


def test_synthetic_calibration_is_deterministic():
    a = ModelCompiler("full").with_synthetic_calibration(5, 10, 42)
    b = ModelCompiler("full").with_synthetic_calibration(5, 10, 42)
    assert a.calibration == b.calibration
    assert len(a.calibration) == 10
    assert all(len(row) == 5 for row in a.calibration)
    c = ModelCompiler("full").with_synthetic_calibration(5, 10, 43)
    assert a.calibration != c.calibration


def test_invalid_inputs_are_rejected():
    import pytest

    with pytest.raises(ValueError):
        ModelCompiler("aggressive")
    with pytest.raises(ValueError):
        # Odd clause count breaks the +/- polarity pairing.
        ModelCompiler("prune").compile_multiclass([[mask_of(4, [0])]] * 2)
    with pytest.raises(ValueError):
        # Calibration row width mismatch.
        ModelCompiler("full").with_calibration([[True, False]]).compile_multiclass(
            [[mask_of(6, [0]), mask_of(6, [1])]] * 2
        )
    with pytest.raises(ValueError):
        # Weight row width != clause count.
        ModelCompiler("prune").compile_cotm([mask_of(4, [0])], [[1, 2]])


def test_live_density_accounting_fixes_the_auto_choice():
    # The density-accounting regression the compile pass fixed, at the
    # mirror level (pinned identically in index.rs / compressed.rs):
    # 9 dead all-exclude clauses + 1 clause including 5 of its 10
    # literals. Stale accounting (postings / total·2F) said 0.05 ->
    # "indexed"; live accounting says 0.5 -> "packed".
    masks = [mask_of(10, [])] * 9 + [mask_of(10, [0, 2, 4, 6, 8])]
    for model in (InvertedIndex(5, masks), CompressedModel(5, masks)):
        stale = model.postings() / (model.num_clauses() * 10)
        assert abs(stale - 0.05) < 1e-12
        assert model.live_clauses() == 1
        assert abs(model.density() - 0.5) < 1e-12
        assert select_engine(stale, 0.05, 0.2) == "indexed"
        assert select_engine(model.density(), 0.05, 0.2) == "packed"
    # And the compile stats agree with the live accounting.
    stats = CompileStats.from_masks(10, masks)
    assert stats.live_clauses == 1
    assert abs(stats.density - 0.5) < 1e-12
    assert stats.length_histogram[HIST_BUCKETS // 2] == 1
