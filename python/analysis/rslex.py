"""Comment- and string-aware token-level lexer for Rust sources.

Dependency-free (stdlib only). This is not a Rust grammar: it produces
a flat token stream — identifiers, numbers, strings, char literals,
lifetimes, single-char punctuation — each tagged with its source line,
plus the comment stream (where ``// lint:allow`` directives live), and
the structural helpers the rules share: bracket matching, ``fn`` body
spans, attribute groups, ``#[cfg(test)]`` spans.

The tricky Rust-isms it does handle, because serving code uses them:
nested block comments, raw strings (``r#"..."#``), byte strings,
char-literal vs lifetime disambiguation (``'a'`` vs ``'a``), and
numeric type suffixes (``0xcbf2u64``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class Tok:
    kind: str  # "ident" | "num" | "str" | "char" | "lifetime" | "punct"
    text: str
    line: int


@dataclass(frozen=True)
class Comment:
    text: str
    line: int


_IDENT_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")
_NUM_RE = re.compile(
    r"0[xX][0-9a-fA-F_]+|0[bB][01_]+|0[oO][0-7_]+"
    r"|\d[\d_]*(?:\.\d[\d_]*)?(?:[eE][+-]?\d+)?"
)
_NUM_SUFFIX_RE = re.compile(r"[iu](?:8|16|32|64|128|size)|f32|f64")
_CHAR_RE = re.compile(r"'(?:\\(?:x[0-9a-fA-F]{2}|u\{[0-9a-fA-F_]+\}|.)|[^'\\])'")
_RAW_STR_RE = re.compile(r'b?r(#*)"')


def lex(src):
    """Lex Rust source into ``(tokens, comments)``."""
    toks = []
    comments = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j < 0 else j
            comments.append(Comment(src[i:j], line))
            i = j
            continue
        if src.startswith("/*", i):
            start_line = line
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    if src[j] == "\n":
                        line += 1
                    j += 1
            comments.append(Comment(src[i:j], start_line))
            i = j
            continue
        if c in "br":
            m = _RAW_STR_RE.match(src, i)
            if m:
                close = '"' + m.group(1)
                j = src.find(close, m.end())
                j = n if j < 0 else j + len(close)
                text = src[i:j]
                toks.append(Tok("str", text, line))
                line += text.count("\n")
                i = j
                continue
        if c == '"' or src.startswith('b"', i):
            j = i + (2 if c == "b" else 1)
            while j < n and src[j] != '"':
                j += 2 if src[j] == "\\" else 1
            j = min(j + 1, n)
            text = src[i:j]
            toks.append(Tok("str", text, line))
            line += text.count("\n")
            i = j
            continue
        if c == "'":
            m = _CHAR_RE.match(src, i)
            if m:
                toks.append(Tok("char", m.group(0), line))
                i = m.end()
            else:
                m = _IDENT_RE.match(src, i + 1)
                end = m.end() if m else i + 1
                toks.append(Tok("lifetime", src[i:end], line))
                i = end
            continue
        if c.isdigit():
            m = _NUM_RE.match(src, i)
            end = m.end()
            s = _NUM_SUFFIX_RE.match(src, end)
            if s:
                end = s.end()
            toks.append(Tok("num", src[i:end], line))
            i = end
            continue
        m = _IDENT_RE.match(src, i)
        if m:
            toks.append(Tok("ident", m.group(0), line))
            i = m.end()
            continue
        toks.append(Tok("punct", c, line))
        i += 1
    return toks, comments


_CLOSE_OF = {"(": ")", "[": "]", "{": "}"}


def match_delim(toks, i):
    """Index of the closer matching the opening delimiter at ``toks[i]``.

    Counts only the opener's own bracket kind — strings/chars/comments
    are already opaque tokens, so this is exact on well-formed code.
    """
    openc = toks[i].text
    close = _CLOSE_OF[openc]
    depth = 0
    for j in range(i, len(toks)):
        t = toks[j]
        if t.kind == "punct":
            if t.text == openc:
                depth += 1
            elif t.text == close:
                depth -= 1
                if depth == 0:
                    return j
    return len(toks) - 1


def attr_groups(toks):
    """Every ``#[...]`` attribute group as ``(start, end, text)``.

    ``text`` is the group's tokens joined without whitespace — enough
    for substring checks like ``"target_feature"`` or ``"cfg(test)"``.
    """
    out = []
    for i in range(len(toks) - 1):
        t = toks[i]
        if t.kind == "punct" and t.text == "#" and toks[i + 1].text == "[":
            end = match_delim(toks, i + 1)
            out.append((i, end, "".join(x.text for x in toks[i : end + 1])))
    return out


def fn_spans(toks):
    """Every ``fn`` item with a body: ``(name, fn_idx, body_open, body_close)``.

    The body opener is the first ``{`` after the name at zero ``()``/
    ``[]`` nesting; a ``;`` there instead means a bodyless declaration.
    Nested fns are reported both standalone and inside their parent.
    """
    spans = []
    for i, t in enumerate(toks):
        if (
            t.kind == "ident"
            and t.text == "fn"
            and i + 1 < len(toks)
            and toks[i + 1].kind == "ident"
        ):
            depth = 0
            j = i + 2
            while j < len(toks):
                x = toks[j]
                if x.kind == "punct":
                    if x.text in "([":
                        depth += 1
                    elif x.text in ")]":
                        depth -= 1
                    elif x.text == "{" and depth == 0:
                        spans.append((toks[i + 1].text, i, j, match_delim(toks, j)))
                        break
                    elif x.text == ";" and depth == 0:
                        break
                j += 1
    return spans


_MODIFIERS = {"pub", "unsafe", "const", "extern", "crate", "in", "super", "self"}


def attrs_before(toks, idx, groups=None):
    """Attr texts attached to the item whose declaration contains token
    ``idx``, walking back over modifiers (``pub``, ``unsafe``, ...) and
    stacked attributes."""
    if groups is None:
        groups = attr_groups(toks)
    by_end = {g[1]: g for g in groups}
    out = []
    j = idx - 1
    while j >= 0:
        t = toks[j]
        if t.kind == "ident" and t.text in _MODIFIERS:
            j -= 1
        elif t.kind == "punct" and t.text in "()":
            j -= 1  # pub(crate)
        elif t.kind == "str" and j >= 1 and toks[j - 1].text == "extern":
            j -= 1  # extern "C"
        elif t.kind == "punct" and t.text == "]" and j in by_end:
            g = by_end[j]
            out.append(g[2])
            j = g[0] - 1
        else:
            break
    return out


def cfg_test_spans(toks):
    """``(first_line, last_line)`` of every item under ``#[cfg(test)]``
    or ``#[test]`` — used to scope rules to non-test code."""
    spans = []
    for s, e, text in attr_groups(toks):
        if "cfg(test)" not in text and text != "#[test]":
            continue
        depth = 0
        j = e + 1
        while j < len(toks):
            x = toks[j]
            if x.kind == "punct":
                if x.text in "([":
                    depth += 1
                elif x.text in ")]":
                    depth -= 1
                elif x.text == "{" and depth == 0:
                    spans.append((toks[s].line, toks[match_delim(toks, j)].line))
                    break
                elif x.text == ";" and depth == 0:
                    break
            j += 1
    return spans


def in_spans(line, spans):
    return any(a <= line <= b for a, b in spans)


def strip_comments(src):
    """Rust source with comments blanked to spaces, layout preserved —
    for the rules that work on raw text spans (R5 anchors)."""
    out = list(src)
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        if c == '"' or src.startswith('b"', i):
            i += 2 if c == "b" else 1
            while i < n and src[i] != '"':
                i += 2 if src[i] == "\\" else 1
            i += 1
            continue
        m = _RAW_STR_RE.match(src, i) if c in "br" else None
        if m:
            close = '"' + m.group(1)
            j = src.find(close, m.end())
            i = n if j < 0 else j + len(close)
            continue
        if c == "'" and _CHAR_RE.match(src, i):
            i = _CHAR_RE.match(src, i).end()
            continue
        if src.startswith("//", i):
            while i < n and src[i] != "\n":
                out[i] = " "
                i += 1
            continue
        if src.startswith("/*", i):
            depth = 1
            out[i] = out[i + 1] = " "
            i += 2
            while i < n and depth:
                if src.startswith("/*", i):
                    depth += 1
                    out[i] = out[i + 1] = " "
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    out[i] = out[i + 1] = " "
                    i += 2
                else:
                    if src[i] != "\n":
                        out[i] = " "
                    i += 1
            continue
        i += 1
    return "".join(out)
