"""R9 — atomic-ordering discipline in the async clause-parallel trainer.

PR 10's ``tm/async_train.rs`` is correct *because* its memory-ordering
story is trivial: workers publish vote deltas and read class-sum
snapshots with ``Relaxed`` (staleness is the design, not a bug), and
the only synchronization point is the partition join, where an
``Acquire`` load pairs with the implicit release of thread join to
check the vote conservation law.  Anything stronger hides a latent
dependency on ordering the algorithm must not have; anything weaker at
the join turns the lost-update check into a race.

So, everywhere in ``tm/async_train.rs``:

* every ``Ordering::<X>`` must use ``Relaxed``, ``Acquire`` or
  ``Release`` — ``SeqCst`` and ``AcqRel`` are banned outright (if the
  tier needs them, the snapshot contract in the module doc is wrong
  and must be re-argued, not patched around);
* ``Acquire``/``Release`` may appear only inside a ``fn`` whose name
  contains ``join`` — the hot publish/read path stays ``Relaxed``;
* at least one ``Acquire`` must exist inside a join fn, or the
  conservation check has been silently downgraded to a relaxed read.

Deliberate exceptions carry ``// lint:allow(r9) <reason>``.
"""

from .. import rslex
from ..engine import Finding

RULE = "r9"
TITLE = "atomic orderings in async_train.rs follow the snapshot contract"
FIXTURE_GOOD = "r9_good"
FIXTURE_BAD = "r9_bad"

TARGET = "rust/src/tm/async_train.rs"

_ALLOWED = {"Relaxed", "Acquire", "Release"}
_JOIN_ONLY = {"Acquire", "Release"}


def _orderings(toks):
    """Every ``Ordering::<name>`` use as ``(token_index, name_token)``.

    rslex emits ``::`` as two ``:`` puncts, so the shape is four
    tokens: ident ``Ordering``, ``:``, ``:``, ident.
    """
    out = []
    for i in range(len(toks) - 3):
        if (
            toks[i].kind == "ident"
            and toks[i].text == "Ordering"
            and toks[i + 1].kind == "punct"
            and toks[i + 1].text == ":"
            and toks[i + 2].kind == "punct"
            and toks[i + 2].text == ":"
            and toks[i + 3].kind == "ident"
        ):
            out.append((i + 3, toks[i + 3]))
    return out


def _enclosing_fns(spans, idx):
    """Names of every fn whose body token-span contains ``idx``."""
    return [name for name, _fi, b0, b1 in spans if b0 <= idx <= b1]


def check(tree):
    if not tree.exists(TARGET):
        if tree.fixture:
            return []
        return [
            Finding(
                RULE,
                TARGET,
                1,
                "async trainer surface missing from the live tree — the "
                "atomic-ordering contract has nothing to bind to",
            )
        ]
    toks, _ = tree.lexed(TARGET)
    spans = rslex.fn_spans(toks)
    out = []
    join_has_acquire = False
    for idx, tok in _orderings(toks):
        name = tok.text
        fns = _enclosing_fns(spans, idx)
        in_join = any("join" in f for f in fns)
        if name not in _ALLOWED:
            out.append(
                Finding(
                    RULE,
                    TARGET,
                    tok.line,
                    f"Ordering::{name} is outside the snapshot contract — "
                    "the async tier runs on Relaxed vote traffic plus one "
                    "Acquire at the partition join; SeqCst/AcqRel signal a "
                    "hidden ordering dependency the design forbids",
                )
            )
            continue
        if name in _JOIN_ONLY and not in_join:
            where = fns[-1] if fns else "module scope"
            out.append(
                Finding(
                    RULE,
                    TARGET,
                    tok.line,
                    f"Ordering::{name} in `{where}` — Acquire/Release are "
                    "reserved for the partition join (fns named *join*); "
                    "the publish/read hot path must stay Relaxed",
                )
            )
            continue
        if name == "Acquire" and in_join:
            join_has_acquire = True
    if not join_has_acquire:
        out.append(
            Finding(
                RULE,
                TARGET,
                1,
                "no Ordering::Acquire inside a join fn — the vote "
                "conservation check no longer synchronizes with the "
                "workers' publishes and cannot detect lost updates",
            )
        )
    return out
