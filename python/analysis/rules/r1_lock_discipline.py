"""R1 — lock discipline: no bare ``.lock().unwrap()`` / ``.lock().expect(...)``.

A worker that panics while holding a mutex poisons it; a bare unwrap on
the next acquire then cascades the panic through every thread touching
the lock (the failure PR 2 and PR 5 fixed by hand in stats.rs and
pool.rs).  The sanctioned pattern is the shared poison-tolerant helper
``util::lock_unpoisoned`` (``lock().unwrap_or_else(|p| p.into_inner())``),
which this rule does not match.  Tests that deliberately poison a mutex
annotate the bare lock with ``// lint:allow(r1) <reason>``.
"""

from ..engine import Finding

RULE = "r1"
TITLE = "lock discipline: bare .lock().unwrap()/.expect() cascades poisoning"
FIXTURE_GOOD = "r1_good"
FIXTURE_BAD = "r1_bad"


def check(tree):
    out = []
    for rel in tree.rust_files():
        toks, _ = tree.lexed(rel)
        for i in range(len(toks) - 5):
            if (
                toks[i].text == "."
                and toks[i + 1].text == "lock"
                and toks[i + 2].text == "("
                and toks[i + 3].text == ")"
                and toks[i + 4].text == "."
                and toks[i + 5].text in ("unwrap", "expect")
            ):
                out.append(
                    Finding(
                        RULE,
                        rel,
                        toks[i + 5].line,
                        f".lock().{toks[i + 5].text}() cascades a poisoned "
                        "mutex — use util::lock_unpoisoned",
                    )
                )
    return out
