"""R8 — compile pipeline: the serving path builds engines from the
shared compiled artifact, never from raw models.

PR 8 moved dead-clause pruning, fire-order clause reordering, and
per-clause plan selection into one load-time compile pass
(``tm/compile.rs``). Two drift hazards follow:

* ``server.rs`` regrows a direct ``<Engine>::from_model(..)`` call —
  the model is then compiled once per engine family (or not at all),
  ``auto-*`` selection reads a density the engines don't share, and a
  non-default ``compile`` mode silently bypasses those backends.
* an engine's ``from_model`` convenience constructor stops routing
  through ``from_compiled`` — the engine regrows a private prune/plan
  heuristic and the bit-for-bit artifact contract splits per family.

So, in non-test code: ``server.rs`` must run ``ModelCompiler`` and
build engines via ``from_compiled`` only, and every ``from_model``
constructor in the engine files must delegate to ``from_compiled``.
Deliberate exceptions carry ``// lint:allow(r8) <reason>``.
"""

from .. import rslex
from ..engine import Finding

RULE = "r8"
TITLE = "compile pipeline: serving engines build from the compiled artifact"
FIXTURE_GOOD = "r8_good"
FIXTURE_BAD = "r8_bad"

SERVER = "rust/src/coordinator/server.rs"
ENGINES = (
    "rust/src/tm/fast_infer.rs",
    "rust/src/tm/index.rs",
    "rust/src/tm/compressed.rs",
)


def _non_test_tokens(tree, rel):
    toks, _ = tree.lexed(rel)
    spans = rslex.cfg_test_spans(toks)
    return toks, spans


def _check_server(tree):
    out = []
    toks, test_spans = _non_test_tokens(tree, SERVER)
    live = [t for t in toks if not rslex.in_spans(t.line, test_spans)]
    for t in live:
        if t.kind == "ident" and t.text == "from_model":
            out.append(
                Finding(
                    RULE,
                    SERVER,
                    t.line,
                    "serving path builds an engine from a raw model — route "
                    "through ModelCompiler/from_compiled so prune, reorder "
                    "and plan selection run once per model, not per engine",
                )
            )
    idents = {t.text for t in live if t.kind == "ident"}
    if "from_compiled" not in idents:
        out.append(
            Finding(
                RULE,
                SERVER,
                1,
                "server.rs never builds an engine from_compiled — the "
                "serving path bypasses the compile pass entirely",
            )
        )
    elif "ModelCompiler" not in idents:
        out.append(
            Finding(
                RULE,
                SERVER,
                1,
                "server.rs consumes compiled artifacts but never runs "
                "ModelCompiler — the compile-mode knob cannot take effect",
            )
        )
    return out


def _check_engine(tree, rel):
    out = []
    toks, test_spans = _non_test_tokens(tree, rel)
    for name, fi, b0, b1 in rslex.fn_spans(toks):
        if name != "from_model" or rslex.in_spans(toks[fi].line, test_spans):
            continue
        body = {t.text for t in toks[b0 : b1 + 1] if t.kind == "ident"}
        if "from_compiled" not in body:
            out.append(
                Finding(
                    RULE,
                    rel,
                    toks[fi].line,
                    "from_model does not delegate to from_compiled — the "
                    "engine is rebuilding its own prune/plan pipeline "
                    "outside the shared compile pass",
                )
            )
    return out


def check(tree):
    surfaces = (SERVER,) + ENGINES
    missing = [rel for rel in surfaces if not tree.exists(rel)]
    if missing and not tree.fixture:
        return [
            Finding(
                RULE, rel, 1, "compile-pipeline surface missing from the live tree"
            )
            for rel in missing
        ]
    out = []
    if tree.exists(SERVER):
        out.extend(_check_server(tree))
    for rel in ENGINES:
        if tree.exists(rel):
            out.extend(_check_engine(tree, rel))
    return out
