"""R2 — panic containment: every thread entry in ``coordinator/`` must
reach ``catch_unwind`` or a ``JobGuard``.

A panic that escapes a worker closure kills the thread silently and
leaks its in-flight slot (the PR 5 pool/batcher hand-fix).  A spawn
passes if its argument span mentions ``catch_unwind``/``JobGuard``
directly, or calls a same-file fn whose body does (one level of
transitivity — batcher.rs spawns a closure that calls ``run_flush``,
and the catch lives there).
"""

from .. import rslex
from ..engine import Finding

RULE = "r2"
TITLE = "panic containment: coordinator spawns must reach catch_unwind/JobGuard"
FIXTURE_GOOD = "r2_good"
FIXTURE_BAD = "r2_bad"

_GUARDS = {"catch_unwind", "JobGuard"}


def _is_thread_spawn(toks, i):
    """True when ``toks[i]`` (= ident ``spawn``) is a thread spawn call:
    ``.spawn(`` (Builder / scope APIs) or ``thread::spawn(``."""
    if i + 1 >= len(toks) or toks[i + 1].text != "(":
        return False
    prev = toks[i - 1] if i > 0 else None
    if prev is not None and prev.text == ".":
        return True
    return (
        prev is not None
        and prev.text == ":"
        and i >= 3
        and toks[i - 2].text == ":"
        and toks[i - 3].text == "thread"
    )


def _guarded_fns(toks):
    names = set()
    for name, _, b0, b1 in rslex.fn_spans(toks):
        if any(
            t.kind == "ident" and t.text in _GUARDS for t in toks[b0 : b1 + 1]
        ):
            names.add(name)
    return names


def check(tree):
    out = []
    for rel in tree.rust_files():
        if "coordinator/" not in rel:
            continue
        toks, _ = tree.lexed(rel)
        guarded = None
        for i, t in enumerate(toks):
            if t.kind != "ident" or t.text != "spawn" or not _is_thread_spawn(toks, i):
                continue
            if guarded is None:
                guarded = _guarded_fns(toks)
            close = rslex.match_delim(toks, i + 1)
            idents = {
                x.text for x in toks[i + 1 : close + 1] if x.kind == "ident"
            }
            if idents & (_GUARDS | guarded):
                continue
            out.append(
                Finding(
                    RULE,
                    rel,
                    t.line,
                    "thread spawn whose closure never reaches "
                    "catch_unwind or a JobGuard — an escaping panic "
                    "kills the worker and leaks its slot",
                )
            )
    return out
