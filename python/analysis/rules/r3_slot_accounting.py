"""R3 — slot accounting: an in-flight/queue-depth increment must pair
with a release in the same function.

``submit()`` takes a slot with ``in_flight.fetch_add``; every exit path
must give it back (``fetch_sub`` on the reject path, ``abort_submit``
on error paths, or a ``JobGuard`` whose Drop releases).  A function
that increments one of the counters without any release primitive in
its body leaks capacity until restart.
"""

from .. import rslex
from ..engine import Finding

RULE = "r3"
TITLE = "slot accounting: counter increments need a paired release"
FIXTURE_GOOD = "r3_good"
FIXTURE_BAD = "r3_bad"

_COUNTERS = {"in_flight", "inflight", "queue_depth", "depth"}
_RELEASES = {"fetch_sub", "abort_submit", "JobGuard"}


def check(tree):
    out = []
    for rel in tree.rust_files():
        if "coordinator/" not in rel:
            continue
        toks, _ = tree.lexed(rel)
        for name, _, b0, b1 in rslex.fn_spans(toks):
            body = toks[b0 : b1 + 1]
            incs = [
                body[i]
                for i in range(2, len(body))
                if body[i].text == "fetch_add"
                and body[i - 1].text == "."
                and body[i - 2].kind == "ident"
                and body[i - 2].text in _COUNTERS
            ]
            if not incs:
                continue
            idents = {t.text for t in body if t.kind == "ident"}
            if idents & _RELEASES:
                continue
            out.append(
                Finding(
                    RULE,
                    rel,
                    incs[0].line,
                    f"`{name}` increments an in-flight counter with no "
                    "paired release (fetch_sub / abort_submit / "
                    "JobGuard) — a panic or early return leaks the slot",
                )
            )
    return out
