"""R4 — unsafe audit: ``unsafe`` lives only in ``tm/simd.rs``, and only
as ``#[target_feature]`` kernels plus the dispatch blocks that call
them behind runtime feature detection.

The crate is ``#![deny(unsafe_code)]`` everywhere else (Cargo.toml
``[lints.rust]`` + the crate-root attribute); this rule is the
toolchain-less mirror of that bar, plus the structure the attribute
cannot express: an ``unsafe fn`` must carry ``#[target_feature]``
(x86 AVX2/AVX-512 or aarch64 NEON), an ``unsafe {}`` block must call
one of those kernels, and the file must contain a runtime detection
macro (``is_x86_feature_detected!`` / ``is_aarch64_feature_detected!``)
guarding the dispatch.
"""

from .. import rslex
from ..engine import Finding

RULE = "r4"
TITLE = "unsafe audit: unsafe only in tm/simd.rs as feature-gated kernels"
FIXTURE_GOOD = "r4_good"
FIXTURE_BAD = "r4_bad"

_ALLOWED_SUFFIX = "tm/simd.rs"
_DETECT_MACROS = {"is_x86_feature_detected", "is_aarch64_feature_detected"}


def check(tree):
    out = []
    for rel in tree.rust_files():
        toks, _ = tree.lexed(rel)
        unsafe_idx = [
            i for i, t in enumerate(toks) if t.kind == "ident" and t.text == "unsafe"
        ]
        if not unsafe_idx:
            continue
        if not rel.endswith(_ALLOWED_SUFFIX):
            for i in unsafe_idx:
                out.append(
                    Finding(
                        RULE,
                        rel,
                        toks[i].line,
                        "unsafe outside tm/simd.rs — the crate is "
                        "#![deny(unsafe_code)]; vector kernels are the "
                        "only audited exception",
                    )
                )
            continue

        groups = rslex.attr_groups(toks)
        target_fns = set()
        for name, fi, _, _ in rslex.fn_spans(toks):
            if any("target_feature" in a for a in rslex.attrs_before(toks, fi, groups)):
                target_fns.add(name)
        idents = {t.text for t in toks if t.kind == "ident"}
        if not idents & _DETECT_MACROS:
            out.append(
                Finding(
                    RULE,
                    rel,
                    1,
                    "unsafe kernels without a runtime feature-detection "
                    "macro in the file — dispatch must be guarded by "
                    "is_x86_feature_detected!/is_aarch64_feature_detected!",
                )
            )

        for i in unsafe_idx:
            nxt = toks[i + 1] if i + 1 < len(toks) else None
            if nxt is not None and nxt.text == "fn":
                if any(
                    "target_feature" in a
                    for a in rslex.attrs_before(toks, i, groups)
                ):
                    continue
                out.append(
                    Finding(
                        RULE,
                        rel,
                        toks[i].line,
                        "unsafe fn without #[target_feature] — only "
                        "feature-gated vector kernels may be unsafe",
                    )
                )
            elif nxt is not None and nxt.text == "{":
                close = rslex.match_delim(toks, i + 1)
                inner = {
                    x.text for x in toks[i + 1 : close + 1] if x.kind == "ident"
                }
                if inner & target_fns:
                    continue
                out.append(
                    Finding(
                        RULE,
                        rel,
                        toks[i].line,
                        "unsafe block that does not call a "
                        "#[target_feature] kernel defined in this file",
                    )
                )
            elif nxt is not None and nxt.text == "impl":
                out.append(
                    Finding(
                        RULE,
                        rel,
                        toks[i].line,
                        "unsafe impl is outside the audited kernel shape",
                    )
                )
    return out
