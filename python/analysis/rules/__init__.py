"""The invariant catalog, one module per rule.

Each rule module exports:

* ``RULE``          — the short id used in findings and ``lint:allow``
* ``TITLE``         — one-line human description
* ``FIXTURE_GOOD``/``FIXTURE_BAD`` — mini-repo directory names under
  ``python/tests/fixtures/analysis/`` proving the rule stays silent /
  fires (the meta-test in test_analysis.py enforces the pair exists)
* ``check(tree)``   — returns a list of ``engine.Finding``

docs/INVARIANTS.md narrates what each contract is and which PR's
hand-fix it fossilizes.
"""

from . import (
    r1_lock_discipline,
    r2_panic_containment,
    r3_slot_accounting,
    r4_unsafe_audit,
    r5_golden_drift,
    r6_registry_coverage,
    r7_ratchet,
    r8_compile_pipeline,
    r9_atomic_ordering,
)

ALL_RULES = [
    r1_lock_discipline,
    r2_panic_containment,
    r3_slot_accounting,
    r4_unsafe_audit,
    r5_golden_drift,
    r6_registry_coverage,
    r7_ratchet,
    r8_compile_pipeline,
    r9_atomic_ordering,
]
