"""R5 — golden-vector drift: the hand-duplicated golden constants in
Rust unit tests must equal their Python-mirror twins.

Every core algorithm (hash ring, inverted index, compressed walk,
packed trainer + SplitMix64, SIMD tile layout) is validated on both
sides of the language boundary by the *same* constants, copied by hand.
Nothing machine-checked that the copies match — until this rule: each
probe below names the Rust span and the Python span holding one golden
family, extracts the constants (string-blind for ints, int-blind for
bitstrings) and asserts equality.

Probes compare either an ordered sequence (``exact``) or a multiset
(``multiset`` — used where one side splits a family across several
tests).  On the live tree a missing file or span is itself a finding;
fixture mini-repos run whichever probes their files support (at least
one must run).
"""

from __future__ import annotations

import re

from .. import rslex
from ..engine import Finding

RULE = "r5"
TITLE = "golden-vector drift: Rust test constants == Python mirror constants"
FIXTURE_GOOD = "r5_good"
FIXTURE_BAD = "r5_bad"

# ---------------------------------------------------------------------------
# span capture on comment-stripped text

def _balance(text, i, op, cl):
    """Span text from the opener at ``text[i]`` to its matching closer,
    skipping string literals (class-sum assert messages carry ``{}``)."""
    depth = 0
    in_str = False
    j = i
    while j < len(text):
        c = text[j]
        if in_str:
            if c == "\\":
                j += 2
                continue
            if c == '"':
                in_str = False
        elif c == '"':
            in_str = True
        elif c == op:
            depth += 1
        elif c == cl:
            depth -= 1
            if depth == 0:
                return text[i : j + 1]
        j += 1
    return None


def _nth(text, needle, occurrence):
    pos = -1
    for _ in range(occurrence):
        pos = text.find(needle, pos + 1)
        if pos < 0:
            return -1
    return pos


def _rust_fn_span(text, name, _occ):
    m = re.search(rf"\bfn\s+{re.escape(name)}\b", text)
    if m is None:
        return None, -1
    i = text.find("{", m.end())
    if i < 0:
        return None, -1
    return _balance(text, i, "{", "}"), m.start()


def _py_def_span(text, name, _occ):
    m = re.search(rf"^def\s+{re.escape(name)}\b", text, re.M)
    if m is None:
        return None, -1
    header_end = text.find(":", m.end())
    if header_end < 0:
        return None, -1
    body_start = text.find("\n", header_end) + 1
    if body_start == 0:
        return None, -1
    m2 = re.search(r"^\S", text[body_start:], re.M)
    end = body_start + m2.start() if m2 else len(text)
    return text[body_start:end], m.start()


def _anchor_span(text, anchor, occurrence):
    pos = _nth(text, anchor, occurrence)
    if pos < 0:
        return None, -1
    i = text.find("[", pos + len(anchor))
    if i < 0:
        return None, -1
    return _balance(text, i, "[", "]"), pos


def _line_span(text, anchor, occurrence):
    pos = _nth(text, anchor, occurrence)
    if pos < 0:
        return None, -1
    end = text.find("\n", pos)
    return text[pos : end if end >= 0 else len(text)], pos


_SPAN_KINDS = {
    "fn": _rust_fn_span,
    "def": _py_def_span,
    "anchor": _anchor_span,
    "line": _line_span,
}

# ---------------------------------------------------------------------------
# constant extraction

def _scan_strings(span):
    """``(blanked, strings)``: the span with every string literal's
    chars replaced by spaces (length preserved), plus the literal
    contents with their positions.  Handles Rust ``"``/``b"`` and
    Python ``"``/``'``/triple quotes alike."""
    out = list(span)
    strings = []
    i, n = 0, len(span)
    while i < n:
        c = span[i]
        quote = None
        if span.startswith('"""', i) or span.startswith("'''", i):
            quote = span[i : i + 3]
        elif c in "\"'":
            quote = c
        if quote is None:
            i += 1
            continue
        start = i
        j = i + len(quote)
        while j < n and not span.startswith(quote, j):
            j += 2 if span[j] == "\\" else 1
        content = span[i + len(quote) : j]
        j = min(j + len(quote), n)
        strings.append((start, content))
        for k in range(start, j):
            if out[k] != "\n":
                out[k] = " "
        i = j
    return "".join(out), strings


_INT_RE = re.compile(r"0[xX][0-9a-fA-F_]+|\d[\d_]*")
_BITS_RE = re.compile(r"^[01]{8,}$")
_SIGN_CONTEXT = "[,(={<:"


def _scan_ints(span):
    """``(pos, value, is_hex)`` for every integer literal outside
    strings, with a leading ``-`` folded in when it reads as a sign
    (previous non-space char opens a list/call/assignment)."""
    blanked, _ = _scan_strings(span)
    out = []
    for m in _INT_RE.finditer(blanked):
        a, b = m.span()
        if a > 0 and (blanked[a - 1].isalnum() or blanked[a - 1] in "_."):
            continue
        if b < len(blanked) and blanked[b] == ".":
            continue
        txt = m.group(0).replace("_", "")
        is_hex = txt.lower().startswith("0x")
        v = int(txt, 16) if is_hex else int(txt)
        j = a - 1
        while j >= 0 and blanked[j] in " \t\n":
            j -= 1
        if j >= 0 and blanked[j] == "-":
            k = j - 1
            while k >= 0 and blanked[k] in " \t\n":
                k -= 1
            if k < 0 or blanked[k] in _SIGN_CONTEXT:
                v = -v
        out.append((a, v, is_hex))
    return out


def _extract(span, mode):
    if mode == "ints":
        return [v for _, v, _ in _scan_ints(span)]
    if mode == "wide_ints":
        return [v for _, v, _ in _scan_ints(span) if abs(v) >= 1 << 32]
    if mode == "hex_ints":
        return [v for _, v, h in _scan_ints(span) if h]
    if mode == "bitstrings":
        _, strings = _scan_strings(span)
        return [s for _, s in strings if _BITS_RE.match(s)]
    if mode == "ints_and_bitstrings":
        _, strings = _scan_strings(span)
        tagged = [(p, ("bits", s)) for p, s in strings if _BITS_RE.match(s)]
        tagged += [(p, ("int", v)) for p, v, _ in _scan_ints(span)]
        return [t for _, t in sorted(tagged)]
    raise ValueError(mode)


def _strip_py_comments(text):
    blanked, _ = _scan_strings(text)
    out = list(text)
    for m in re.finditer(r"#[^\n]*", blanked):
        for k in range(*m.span()):
            out[k] = " "
    return "".join(out)


# ---------------------------------------------------------------------------
# the probe table — one entry per hand-duplicated golden family

PROBES = [
    dict(
        name="hashring wide constants",
        rust="rust/src/coordinator/shard.rs",
        rust_spans=[
            ("fn", "fnv1a64_golden_vectors", 1),
            ("fn", "ring_hash_golden_vectors", 1),
        ],
        py="python/tests/test_hashring.py",
        py_spans=[
            ("def", "test_fnv1a64_golden_vectors", 1),
            ("def", "test_ring_hash_golden_vectors", 1),
            ("def", "test_mixer_golden_identity", 1),
        ],
        extract="wide_ints",
        compare="multiset",
    ),
    dict(
        name="hashring routing pairs",
        rust="rust/src/coordinator/shard.rs",
        rust_spans=[
            ("fn", "ring_routing_golden_vectors", 1),
            ("fn", "ring_walk_golden_vectors", 1),
        ],
        py="python/tests/test_hashring.py",
        py_spans=[
            ("def", "test_ring_routing_golden_vectors", 1),
            ("def", "test_ring_walk_golden_vectors", 1),
        ],
        extract="ints",
        compare="exact",
    ),
    dict(
        name="netproto golden frames",
        rust="rust/src/coordinator/net/msg.rs",
        rust_spans=[("fn", "netproto_golden_frames_match_python_mirror", 1)],
        py="python/tests/test_netproto.py",
        py_spans=[("anchor", "GOLDEN_FRAMES = ", 1)],
        extract="hex_ints",
        compare="exact",
    ),
    dict(
        name="invindex class sums",
        rust="rust/src/tm/index.rs",
        rust_spans=[("anchor", "let want_mc = ", 1), ("anchor", "let want_co = ", 1)],
        py="python/tests/test_invindex.py",
        py_spans=[("anchor", "GOLDEN_MC_SUMS = ", 1), ("anchor", "GOLDEN_CO_SUMS = ", 1)],
        extract="ints",
        compare="exact",
    ),
    dict(
        name="compressed class sums",
        rust="rust/src/tm/compressed.rs",
        rust_spans=[("anchor", "let want_mc = ", 1), ("anchor", "let want_co = ", 1)],
        py="python/tests/test_compressed.py",
        py_spans=[("anchor", "GOLDEN_MC_SUMS = ", 1), ("anchor", "GOLDEN_CO_SUMS = ", 1)],
        extract="ints",
        compare="exact",
    ),
    dict(
        name="compressed frequency reorder",
        rust="rust/src/tm/compressed.rs",
        rust_spans=[
            ("anchor", "literal_frequencies(), vec!", 1),
            ("anchor", "c.included(0), &", 1),
            ("anchor", "c.included(1), &", 1),
            ("anchor", "c.included(2), &", 1),
            ("anchor", "c.included(3), &", 2),
        ],
        py="python/tests/test_compressed.py",
        py_spans=[
            ("anchor", "literal_frequencies() == ", 1),
            ("anchor", "REORDER_WANT = ", 1),
        ],
        extract="ints",
        compare="exact",
    ),
    dict(
        name="packedtrain splitmix stream",
        rust="rust/src/tm/trainer_engine.rs",
        rust_spans=[("fn", "splitmix_stream_matches_python_mirror", 1)],
        py="python/tests/test_packedtrain.py",
        py_spans=[("def", "test_splitmix_stream_goldens", 1)],
        extract="wide_ints",
        compare="multiset",
    ),
    dict(
        name="packedtrain chance bitstring",
        rust="rust/src/tm/trainer_engine.rs",
        rust_spans=[("fn", "splitmix_stream_matches_python_mirror", 1)],
        py="python/tests/test_packedtrain.py",
        py_spans=[("def", "test_splitmix_stream_goldens", 1)],
        extract="bitstrings",
        compare="multiset",
    ),
    dict(
        name="packedtrain masks and weights",
        rust="rust/src/tm/trainer_engine.rs",
        rust_spans=[
            ("anchor", "let golden = ", 1),
            ("anchor", "let golden_masks = ", 1),
            ("anchor", "let golden_weights = vec!", 1),
        ],
        py="python/tests/test_packedtrain.py",
        py_spans=[
            ("anchor", "GOLDEN_MC_MASKS = ", 1),
            ("anchor", "GOLDEN_CO_MASKS = ", 1),
            ("anchor", "GOLDEN_CO_WEIGHTS = ", 1),
        ],
        extract="ints_and_bitstrings",
        compare="exact",
    ),
    dict(
        name="asynctrain stream seeds",
        rust="rust/src/tm/async_train.rs",
        rust_spans=[("anchor", "let golden_streams = ", 1)],
        py="python/tests/test_asynctrain.py",
        py_spans=[("anchor", "GOLDEN_STREAMS = ", 1)],
        extract="wide_ints",
        compare="exact",
    ),
    dict(
        name="asynctrain multiclass masks",
        rust="rust/src/tm/async_train.rs",
        rust_spans=[("anchor", "let golden_async = ", 1)],
        py="python/tests/test_asynctrain.py",
        py_spans=[("anchor", "GOLDEN_ASYNC_MC_MASKS = ", 1)],
        extract="bitstrings",
        compare="exact",
    ),
    dict(
        name="asynctrain cotm masks and weights",
        rust="rust/src/tm/async_train.rs",
        rust_spans=[
            ("anchor", "let golden_async_co = ", 1),
            ("anchor", "let golden_async_co_weights = vec!", 1),
        ],
        py="python/tests/test_asynctrain.py",
        py_spans=[
            ("anchor", "GOLDEN_ASYNC_CO_MASKS = ", 1),
            ("anchor", "GOLDEN_ASYNC_CO_WEIGHTS = ", 1),
        ],
        extract="ints_and_bitstrings",
        compare="exact",
    ),
    dict(
        name="simdtile layout goldens",
        rust="rust/src/tm/bitpack.rs",
        rust_spans=[("fn", "tiled_layout_golden_vectors_match_python_mirror", 1)],
        py="python/tests/test_simdtile.py",
        py_spans=[
            ("line", "GOLDEN_FNV = ", 1),
            ("anchor", "GOLDEN_TILE_OUT = ", 1),
            ("def", "test_golden_vectors", 1),
        ],
        extract="hex_ints",
        compare="multiset",
    ),
]


def _collect(text, specs, probe_name, rel, out):
    """Concatenated span text + start offset of the first span; span
    misses become findings."""
    parts = []
    first = -1
    ok = True
    for kind, needle, occ in specs:
        span, pos = _SPAN_KINDS[kind](text, needle, occ)
        if span is None:
            out.append(
                Finding(
                    RULE,
                    rel,
                    1,
                    f"probe '{probe_name}': {kind} span {needle!r} "
                    f"(occurrence {occ}) not found — golden family moved "
                    "without updating the probe table",
                )
            )
            ok = False
            continue
        if first < 0:
            first = pos
        parts.append(span)
    return ("\n".join(parts) if ok else None), first


def check(tree):
    out = []
    ran = 0
    for probe in PROBES:
        have_rust = tree.exists(probe["rust"])
        have_py = tree.exists(probe["py"])
        if not (have_rust and have_py):
            if tree.fixture:
                continue
            for rel, have in ((probe["rust"], have_rust), (probe["py"], have_py)):
                if not have:
                    out.append(
                        Finding(
                            RULE,
                            rel,
                            1,
                            f"probe '{probe['name']}': file missing from "
                            "the live tree",
                        )
                    )
            continue
        rust_text = rslex.strip_comments(tree.read(probe["rust"]))
        py_text = _strip_py_comments(tree.read(probe["py"]))
        rust_span, rust_pos = _collect(
            rust_text, probe["rust_spans"], probe["name"], probe["rust"], out
        )
        py_span, _ = _collect(
            py_text, probe["py_spans"], probe["name"], probe["py"], out
        )
        if rust_span is None or py_span is None:
            continue
        ran += 1
        rust_vals = _extract(rust_span, probe["extract"])
        py_vals = _extract(py_span, probe["extract"])
        line = rust_text[:rust_pos].count("\n") + 1 if rust_pos >= 0 else 1
        if probe["compare"] == "multiset":
            a, b = sorted(map(repr, rust_vals)), sorted(map(repr, py_vals))
        else:
            a, b = list(map(repr, rust_vals)), list(map(repr, py_vals))
        if a != b:
            diff = next(
                (
                    f"first divergence at #{k}: rust={x} python={y}"
                    for k, (x, y) in enumerate(zip(a, b))
                    if x != y
                ),
                f"rust has {len(a)} constants, python has {len(b)}",
            )
            out.append(
                Finding(
                    RULE,
                    probe["rust"],
                    line,
                    f"probe '{probe['name']}': golden constants diverge "
                    f"from {probe['py']} ({diff})",
                )
            )
    if ran == 0 and not out:
        out.append(
            Finding(
                RULE,
                "python/analysis/rules/r5_golden_drift.py",
                1,
                "no golden-vector probe could run against this tree",
            )
        )
    return out
