"""R7 — panic-path ratchet: per-file counts of ``unwrap()``,
``expect(``, panic-family macros and slice indexing in non-test serving
code (``coordinator/`` + ``tm/``) are pinned in
``python/analysis/ratchet.json`` and may only go down.

PR 3 burned a whole satellite hand-removing panic paths from
booleanize/split/stats/config; the ratchet makes the count a reviewed
artifact.  Any movement — up OR down — must touch ratchet.json
(``python3 -m analysis --update-ratchet``), so the diff is the audit
trail: regressions are rejected, improvements are re-pinned.
"""

import json

from .. import rslex
from ..engine import Finding

RULE = "r7"
TITLE = "panic-path ratchet: unwrap/expect/panic!/indexing counts only decrease"
FIXTURE_GOOD = "r7_good"
FIXTURE_BAD = "r7_bad"

RATCHET = "python/analysis/ratchet.json"
_SCOPES = ("rust/src/coordinator/", "rust/src/tm/")
_PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented", "assert"}
_KEYS = ("unwrap", "expect", "panic", "index")

# Idents that read as keywords before `[` — slice patterns, array type
# syntax and expression positions that are not an indexing operation.
_NON_INDEX_PREV = {
    "mut", "ref", "in", "as", "return", "move", "else", "match", "if",
    "while", "for", "loop", "break", "continue", "dyn", "impl", "where",
    "box", "let", "static", "const", "pub", "crate", "unsafe", "fn",
}


def counts_for(tree, rel):
    toks, _ = tree.lexed(rel)
    test_spans = rslex.cfg_test_spans(toks)
    c = dict.fromkeys(_KEYS, 0)
    for i, t in enumerate(toks):
        if rslex.in_spans(t.line, test_spans):
            continue
        nxt = toks[i + 1].text if i + 1 < len(toks) else ""
        if t.kind == "ident" and t.text == "unwrap" and nxt == "(":
            c["unwrap"] += 1
        elif t.kind == "ident" and t.text == "expect" and nxt == "(":
            c["expect"] += 1
        elif t.kind == "ident" and t.text in _PANIC_MACROS and nxt == "!":
            c["panic"] += 1
        elif t.kind == "punct" and t.text == "[" and i > 0:
            prev = toks[i - 1]
            if (prev.kind == "ident" and prev.text not in _NON_INDEX_PREV) or (
                prev.kind == "punct" and prev.text in ")]"
            ):
                c["index"] += 1
    return c


def live_counts(tree):
    return {
        rel: counts_for(tree, rel)
        for rel in tree.rust_files()
        if any(rel.startswith(s) for s in _SCOPES)
    }


def check(tree):
    live = live_counts(tree)
    if not tree.exists(RATCHET):
        if tree.fixture and not live:
            return []
        return [
            Finding(
                RULE,
                RATCHET,
                1,
                "ratchet.json missing — run python3 -m analysis "
                "--update-ratchet and review the pinned counts",
            )
        ]
    pinned = json.loads(tree.read(RATCHET))
    out = []
    for rel in sorted(set(live) | set(pinned)):
        if rel not in pinned:
            out.append(
                Finding(
                    RULE,
                    rel,
                    1,
                    "new serving file not pinned in ratchet.json — run "
                    "--update-ratchet and review its panic-path budget",
                )
            )
            continue
        if rel not in live:
            out.append(
                Finding(
                    RULE,
                    RATCHET,
                    1,
                    f"stale ratchet entry for removed file {rel}",
                )
            )
            continue
        for k in _KEYS:
            now, was = live[rel][k], pinned[rel].get(k, 0)
            if now > was:
                out.append(
                    Finding(
                        RULE,
                        rel,
                        1,
                        f"{k} count rose {was} -> {now} — the panic-path "
                        "ratchet only goes down (fix the code, or justify "
                        "and re-pin via --update-ratchet)",
                    )
                )
            elif now < was:
                out.append(
                    Finding(
                        RULE,
                        rel,
                        1,
                        f"{k} count fell {was} -> {now} — good; tighten the "
                        "pin via --update-ratchet so it cannot bounce back",
                    )
                )
    return out


def update(tree):
    """Re-pin ratchet.json to the live tree; returns the path written."""
    path = tree.root / RATCHET
    path.write_text(
        json.dumps(live_counts(tree), indent=2, sort_keys=True) + "\n",
        encoding="utf-8",
    )
    return str(path)
