"""R6 — registry coverage: backends and config knobs cannot be wired
into some surfaces and forgotten in others.

Every backend name ``router.rs`` registers must be visible in (a) the
CLI USAGE text, (b) the cross-engine conformance matrix
``tests/engine_matrix.rs``, and (c) ``tmtd selfcheck``.  For (b)/(c) a
surface that iterates ``Backend::ALL`` covers every name at once —
that is the preferred, drift-proof form.

Every ``ServeConfig`` field must have a TOML parse in ``from_toml``, a
check in ``validate`` (or be on the type-level allowlist below, where
parsing itself is the validation), and a USAGE mention.

Every subcommand routed by ``main.rs``'s ``run`` dispatcher (the
string-literal match arms) must appear in the USAGE text — a command
that exists but is undocumented is unreachable by anyone reading
``tmtd help``. Trees without a ``run`` dispatcher (fixtures) skip this
check.
"""

import re

from .. import rslex
from ..engine import Finding

RULE = "r6"
TITLE = "registry coverage: backends/knobs present in USAGE, matrix, selfcheck"
FIXTURE_GOOD = "r6_good"
FIXTURE_BAD = "r6_bad"

ROUTER = "rust/src/coordinator/router.rs"
CLI = "rust/src/cli.rs"
MAIN = "rust/src/main.rs"
MATRIX = "tests/engine_matrix.rs"
CONFIG = "rust/src/config/mod.rs"

_SURFACES = (ROUTER, CLI, MAIN, MATRIX, CONFIG)

# Fields whose parse IS the validation: enum/level names are rejected
# by their own parser, and these two carry no range constraint.
_TYPE_VALIDATED = {
    "artifacts_dir": "free-form path, any value is legal",
    "wta": "enum parse rejects unknown kinds",
    "simd": "SimdChoice::parse rejects unknown level names",
    "batch_timeout_us": "every u64 is a legal timeout",
    "compile": "CompileMode::parse rejects unknown mode names",
    "listen": "free-form bind address; `tmtd shard` errors on bind",
    "trainer": "TrainerChoice::parse rejects unknown trainer names",
}

# Matches raw source ("Backend::ALL") and token-joined fn-body text,
# where the lexer splits "::" into two ":" puncts ("Backend : : ALL").
_ALL_RE = re.compile(r"Backend\s*:\s*:\s*ALL")


def _backend_names(tree):
    """The registry: string literals in router.rs's ``fn name`` body."""
    toks, _ = tree.lexed(ROUTER)
    for name, _, b0, b1 in rslex.fn_spans(toks):
        if name == "name":
            return [
                t.text.strip('"')
                for t in toks[b0 : b1 + 1]
                if t.kind == "str"
            ]
    return []


def _fn_body_text(tree, rel, fn_name):
    toks, _ = tree.lexed(rel)
    for name, _, b0, b1 in rslex.fn_spans(toks):
        if name == fn_name:
            return " ".join(t.text for t in toks[b0 : b1 + 1])
    return None


def _run_subcommands(tree):
    """String-literal match arms of main.rs's ``run`` dispatcher.

    A literal counts when followed by ``=>`` (single-char lexed as
    ``=`` ``>``) or ``|`` (multi-pattern arm). Returns ``None`` when no
    ``run`` fn exists so fixture trees skip the check.
    """
    toks, _ = tree.lexed(MAIN)
    for name, _, b0, b1 in rslex.fn_spans(toks):
        if name != "run":
            continue
        subs = []
        for k in range(b0, b1):
            t = toks[k]
            if t.kind != "str" or k + 1 > b1:
                continue
            nxt = toks[k + 1].text
            if nxt in ("=", "|"):
                sub = t.text.strip('"')
                if sub:
                    subs.append(sub)
        return subs
    return None


def _serve_fields(tree):
    toks, _ = tree.lexed(CONFIG)
    for i, t in enumerate(toks):
        if t.text == "ServeConfig" and i > 0 and toks[i - 1].text == "struct":
            j = i + 1
            while j < len(toks) and toks[j].text != "{":
                j += 1
            close = rslex.match_delim(toks, j)
            fields = []
            for k in range(j + 1, close):
                if (
                    toks[k].kind == "ident"
                    and k + 1 < len(toks)
                    and toks[k + 1].text == ":"
                    and toks[k - 1].text in ("pub", "{", ",")
                ):
                    fields.append(toks[k].text)
            return fields
    return []


def check(tree):
    missing = [rel for rel in _SURFACES if not tree.exists(rel)]
    if missing:
        if tree.fixture:
            return []
        return [
            Finding(RULE, rel, 1, "registry surface missing from the live tree")
            for rel in missing
        ]

    out = []
    backends = _backend_names(tree)
    if not backends:
        out.append(
            Finding(RULE, ROUTER, 1, "no backend names found in Backend::name()")
        )

    usage_text = tree.read(CLI)
    for b in backends:
        if b not in usage_text:
            out.append(
                Finding(
                    RULE,
                    CLI,
                    1,
                    f"backend '{b}' is registered in router.rs but absent "
                    "from the CLI USAGE text",
                )
            )

    matrix_text = tree.read(MATRIX)
    matrix_covers_all = _ALL_RE.search(matrix_text) is not None
    for b in backends:
        if not matrix_covers_all and b not in matrix_text:
            out.append(
                Finding(
                    RULE,
                    MATRIX,
                    1,
                    f"backend '{b}' is not exercised by the engine matrix "
                    "(name it, or iterate Backend::ALL)",
                )
            )

    selfcheck = _fn_body_text(tree, MAIN, "cmd_selfcheck")
    if selfcheck is None:
        out.append(Finding(RULE, MAIN, 1, "cmd_selfcheck not found in main.rs"))
    else:
        covers_all = _ALL_RE.search(selfcheck) is not None
        for b in backends:
            if not covers_all and f'"{b}"' not in selfcheck:
                out.append(
                    Finding(
                        RULE,
                        MAIN,
                        1,
                        f"backend '{b}' never surfaces in tmtd selfcheck "
                        "(print it, or iterate Backend::ALL)",
                    )
                )

    subs = _run_subcommands(tree)
    for sub in subs or []:
        if sub not in usage_text:
            out.append(
                Finding(
                    RULE,
                    CLI,
                    1,
                    f"subcommand '{sub}' is dispatched by main.rs run() but "
                    "absent from the CLI USAGE text",
                )
            )

    fields = _serve_fields(tree)
    if not fields:
        out.append(Finding(RULE, CONFIG, 1, "ServeConfig struct not found"))
    from_toml = _fn_body_text(tree, CONFIG, "from_toml") or ""
    validate = _fn_body_text(tree, CONFIG, "validate") or ""
    for f in fields:
        if not re.search(rf"\b{f}\b", from_toml):
            out.append(
                Finding(
                    RULE,
                    CONFIG,
                    1,
                    f"ServeConfig field '{f}' has no TOML parse in from_toml",
                )
            )
        if f not in _TYPE_VALIDATED and not re.search(rf"\b{f}\b", validate):
            out.append(
                Finding(
                    RULE,
                    CONFIG,
                    1,
                    f"ServeConfig field '{f}' is never checked in validate() "
                    "and is not on the type-validated allowlist",
                )
            )
        if f not in usage_text:
            out.append(
                Finding(
                    RULE,
                    CLI,
                    1,
                    f"ServeConfig field '{f}' is undocumented in the CLI "
                    "USAGE text",
                )
            )
    return out
