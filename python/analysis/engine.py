"""Rule runner: tree walking, findings, the ``lint:allow`` escape hatch.

A ``Tree`` is either the live repo or a fixture mini-repo under
``python/tests/fixtures/analysis/`` (same relative layout, a few files).
Rules never read the filesystem directly — they go through the tree's
cached ``read``/``lexed``/``rust_files`` so fixtures and the live repo
are interchangeable.

Suppression: ``// lint:allow(<rule>) <reason>`` on the finding's line
or the line directly above silences that one finding.  A directive
without a reason is itself reported (rule id ``allow``) — the escape
hatch must say why.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from pathlib import Path

from . import rslex


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    message: str

    def render(self):
        return f"{self.path}:{self.line} [{self.rule}] {self.message}"


_ALLOW_RE = re.compile(r"lint:allow\((r\d+)\)\s*(.*)")

# Where Rust sources live, relative to the tree root.  Fixture
# mini-repos replicate the same layout, so one list serves both.
_RUST_SUBDIRS = ("rust/src", "tests", "benches", "examples")


class Tree:
    """A repo (or fixture mini-repo) the rules run against.

    ``fixture=True`` relaxes the whole-repo rules (R5/R6/R7): surfaces
    absent from a mini-repo are skipped instead of reported missing.
    """

    def __init__(self, root, fixture=False):
        self.root = Path(root)
        self.fixture = fixture
        self._text = {}
        self._lexed = {}

    def exists(self, rel):
        return (self.root / rel).is_file()

    def read(self, rel):
        if rel not in self._text:
            self._text[rel] = (self.root / rel).read_text(encoding="utf-8")
        return self._text[rel]

    def lexed(self, rel):
        if rel not in self._lexed:
            self._lexed[rel] = rslex.lex(self.read(rel))
        return self._lexed[rel]

    def rust_files(self):
        out = []
        for sub in _RUST_SUBDIRS:
            base = self.root / sub
            if base.is_dir():
                out += [
                    str(p.relative_to(self.root)).replace("\\", "/")
                    for p in base.rglob("*.rs")
                ]
        return sorted(out)


def all_rules():
    from .rules import ALL_RULES

    return ALL_RULES


def directives(tree, rel):
    """``lint:allow`` directives in one file: ``[(line, rule, reason)]``."""
    _, comments = tree.lexed(rel)
    out = []
    for c in comments:
        m = _ALLOW_RE.search(c.text)
        if m:
            out.append((c.line, m.group(1), m.group(2).strip()))
    return out


def run(tree, rules=None):
    """Run ``rules`` (default: all) over ``tree`` and return the
    surviving findings, sorted, suppression applied."""
    findings = []
    for rule in rules if rules is not None else all_rules():
        findings += list(rule.check(tree))

    dcache = {}

    def file_directives(rel):
        if rel not in dcache:
            try:
                dcache[rel] = directives(tree, rel)
            except OSError:
                dcache[rel] = []
        return dcache[rel]

    kept = []
    for f in findings:
        ds = file_directives(f.path) if f.path.endswith(".rs") else []
        if any(
            rule == f.rule and line in (f.line, f.line - 1) and reason
            for line, rule, reason in ds
        ):
            continue
        kept.append(f)

    for rel in tree.rust_files():
        for line, rule, reason in file_directives(rel):
            if not reason:
                kept.append(
                    Finding(
                        "allow",
                        rel,
                        line,
                        f"lint:allow({rule}) without a reason — say why the "
                        "escape hatch applies",
                    )
                )

    return sorted(set(kept), key=lambda f: (f.path, f.line, f.rule))
