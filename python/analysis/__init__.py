"""Toolchain-less static-analysis tier for the Rust tree.

The CI image carries no Rust toolchain (see ROADMAP.md), so the
non-algorithmic serving invariants — poison-tolerant locks, panic
containment on thread entry, exactly-once in-flight slot release,
Rust<->Python golden-vector parity, registry coverage, the panic-path
ratchet — are enforced here, in dependency-free Python, as the first
stage of scripts/verify.sh.

Layout:

* ``rslex``   — comment/string-aware token-level Rust lexer + shared
  structural helpers (bracket matching, fn spans, attribute groups).
* ``engine``  — the rule runner: walks the tree, applies the
  ``// lint:allow(<rule>) <reason>`` escape hatch, renders findings.
* ``rules``   — one module per rule, r1..r7.  Each ships a known-good
  and a known-bad fixture under python/tests/fixtures/analysis/.

Entry points: ``scripts/lint.sh`` (CI), ``python3 -m analysis``
(direct), ``python3 -m analysis --update-ratchet`` (re-pin r7 counts
after a reviewed panic-path change).  The invariant catalog lives in
docs/INVARIANTS.md.
"""

from .engine import Finding, Tree, run  # noqa: F401
