"""CLI: ``python3 -m analysis [--update-ratchet] [root]``.

Exit 0 when every rule is clean, 1 otherwise.  ``--update-ratchet``
re-pins the R7 panic-path counts to the live tree (do this only after
reviewing why a count moved; the diff of ratchet.json is the audit
trail).
"""

import sys
from pathlib import Path

from .engine import Tree, run
from .rules import ALL_RULES
from .rules import r7_ratchet


def main(argv):
    update = "--update-ratchet" in argv
    rest = [a for a in argv if not a.startswith("--")]
    root = Path(rest[0]) if rest else Path(__file__).resolve().parents[2]
    tree = Tree(root)
    if update:
        path = r7_ratchet.update(tree)
        print(f"lint: re-pinned panic-path ratchet at {path}")
        return 0
    findings = run(tree)
    for f in findings:
        print(f.render())
    if findings:
        print(f"lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    rules = ", ".join(r.RULE for r in ALL_RULES)
    print(f"lint: OK ({rules} clean on {len(tree.rust_files())} Rust files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
