"""Python mirror of the asynchronous clause-parallel TM training tier.

Mirrors ``rust/src/tm/async_train.rs`` — the partitioning, stale-vote
snapshot, and RNG-stream contract — so the toolchain-less CI image can
validate the async trainer's algorithm the same way ``packedtrain.py``
validates the deterministic trainers.

What exactly is mirrored
------------------------

The Rust tier has two schedules over the *same* per-(worker, sample)
step function:

* the **threaded** schedule (``std::thread::scope`` workers racing over
  a shared relaxed-atomic vote array) — deliberately nondeterministic,
  validated statistically and by invariant fuzzing;
* the **deterministic** schedule (sample-major round-robin replay of
  the identical step sequence) — bit-reproducible, and the thing this
  file mirrors literal-for-literal.

At ``threads == 1`` the two schedules coincide (one worker, no
interleaving), so the deterministic contract pins the threaded code
path too — that degenerate case is asserted on the Rust side.

The contract, shared golden-for-golden with the Rust unit tests:

* **Partitioning** — global clause slot ``j`` is owned by worker
  ``j % threads``; initial TA states are drawn from a single
  ``SplitMix64(seed)`` in exactly the reference trainer's order
  (class-major, clause order), *then* distributed, so partitioning
  never perturbs initialisation.
* **RNG streams** — ``stream_seed(seed, epoch, lane)`` derives one
  independent SplitMix64 stream per (epoch, lane): lane 0 is the shared
  sample-order shuffle, lane 1 the negative-class draw (every worker
  replays its own copy, so all workers agree on the two touched classes
  of each sample without communicating), lanes 2.. are the per-worker
  feedback streams.
* **Stale votes** — each worker publishes its partition's class-sum
  contribution by differencing against its previous contribution
  (``votes[c] += contrib - last[c]``), then reads the shared total for
  the update probability. Between refreshes other workers' entries are
  stale *by design*; the conservation law ``votes[c] == sum_w last_w[c]``
  must still hold at epoch join (no lost updates on partition
  boundaries).
* **Indexed feedback** — the ``indexed`` engine evaluates owned clauses
  through per-worker literal->clause postings with unsatisfied-literal
  counters (the ``tm/index.rs`` sweep, training-time empty-clause-FIRES
  semantics) kept in sync incrementally after every feedback. Evaluation
  is exact, so ``indexed`` and ``packed`` produce **bit-identical**
  models under the deterministic schedule — asserted in both languages.
"""

from packedtrain import (
    MASK64,
    WORD_BITS,
    ClauseState,
    SplitMix64,
    make_literals,
    pack_literals,
    type_i,
    type_ii,
)

# Fixed odd mixing constants for the stream-seed closed form. These are
# part of the cross-language contract (see the r5 probe): changing them
# changes every async golden vector in both languages at once.
STREAM_EPOCH_MIX = 0xA0761D6478BD642F
STREAM_LANE_MIX = 0xE7037ED1A0B428DB

LANE_ORDER = 0
LANE_NEG = 1
LANE_WORKER0 = 2


def stream_seed(seed, epoch, lane):
    """Closed-form per-(epoch, lane) stream derivation.

    Deliberately *not* ``rng.fork()``: a closed form lets any worker
    (or either language) derive any stream independently, with no
    draw-order coupling between workers.
    """
    root = SplitMix64(seed).next_u64()
    mix = (
        root
        ^ ((epoch * STREAM_EPOCH_MIX) & MASK64)
        ^ ((lane * STREAM_LANE_MIX) & MASK64)
    )
    return SplitMix64(mix).next_u64()


class TrainIndex:
    """Per-worker inverted index over the worker's *owned* clauses.

    Literal -> local-clause postings plus persistent unsatisfied-literal
    counters, exactly the ``tm/index.rs`` sweep structure but with
    training-time semantics (a clause with zero included literals
    FIRES) and incremental maintenance: after every feedback the
    changed include bits are replayed into the postings, so an update
    pays O(touched literals), never O(model).
    """

    def __init__(self, states, n, literals):
        self.n = n
        self.postings = [[] for _ in range(literals)]
        self.required = [0] * len(states)
        for ci, cl in enumerate(states):
            for l, inc in enumerate(cl.include_mask(n)):
                if inc:
                    self.postings[l].append(ci)
                    self.required[ci] += 1
        # Persistent counters, decremented during a sweep and restored
        # afterwards (index.rs convention) — never rebuilt per sample.
        self.counts = list(self.required)

    def fired_flags(self, lits):
        """One sweep: fired flags for every owned clause on this sample.

        A counter can never go below zero: a clause receives at most
        ``required`` decrements (one per included literal that is set).
        """
        fired = [r == 0 for r in self.required]
        for l, on in enumerate(lits):
            if not on:
                continue
            for ci in self.postings[l]:
                self.counts[ci] -= 1
                if self.counts[ci] == 0:
                    fired[ci] = True
        for l, on in enumerate(lits):
            if not on:
                continue
            for ci in self.postings[l]:
                self.counts[ci] += 1
        return fired

    def apply_diff(self, ci, old_words, new_words):
        """Replay one clause's include-mask change into the postings."""
        for w, (ow, nw) in enumerate(zip(old_words, new_words)):
            diff = ow ^ nw
            while diff:
                bit = diff & -diff
                l = w * WORD_BITS + bit.bit_length() - 1
                diff ^= bit
                if nw & bit:
                    self.postings[l].append(ci)
                    self.required[ci] += 1
                    self.counts[ci] += 1
                else:
                    self.postings[l].remove(ci)
                    self.required[ci] -= 1
                    self.counts[ci] -= 1

    def coherent(self, states):
        """Incrementally-maintained index == a fresh build."""
        fresh = TrainIndex(states, self.n, len(self.postings))
        return (
            [sorted(p) for p in self.postings] == fresh.postings
            and self.required == fresh.required
            and self.counts == fresh.required
        )


class _Owned:
    """One clause moved into a worker partition (Rust: ``OwnedClause``)."""

    __slots__ = ("class_", "slot", "state", "weights")

    def __init__(self, class_, slot, state, weights=None):
        self.class_ = class_
        self.slot = slot
        self.state = state
        self.weights = weights  # CoTM only: per-class weight column


class AsyncMultiClassTrainer:
    """Clause-parallel multi-class trainer, deterministic schedule."""

    def __init__(self, params, seed, threads, engine="packed"):
        assert engine in ("packed", "indexed"), engine
        assert threads >= 1
        assert params.clauses % 2 == 0
        self.params = params
        self.seed = seed
        self.threads = threads
        self.engine = engine
        self.epochs_run = 0
        n = params.ta_states
        init_rng = SplitMix64(seed)
        self.parts = [[] for _ in range(threads)]
        for k in range(params.classes):
            for j in range(params.clauses):
                st = ClauseState.init(params.literals(), n, init_rng)
                self.parts[j % threads].append(_Owned(k, j, st))
        self.indexes = None
        if engine == "indexed":
            self.indexes = [
                TrainIndex([oc.state for oc in part], n, params.literals())
                for part in self.parts
            ]

    def epoch(self, features, labels):
        """Sample-major round-robin replay of the threaded schedule."""
        p = self.params
        e = self.epochs_run
        order = list(range(len(features)))
        SplitMix64(stream_seed(self.seed, e, LANE_ORDER)).shuffle(order)
        votes = [0] * p.classes
        last = [[0] * p.classes for _ in range(self.threads)]
        rngs = [
            SplitMix64(stream_seed(self.seed, e, LANE_WORKER0 + w))
            for w in range(self.threads)
        ]
        neg_rngs = [
            SplitMix64(stream_seed(self.seed, e, LANE_NEG))
            for _ in range(self.threads)
        ]
        lits_all = [make_literals(x) for x in features]
        words_all = [pack_literals(x) for x in features]
        for i in order:
            for w in range(self.threads):
                self._step(
                    w, lits_all[i], words_all[i], labels[i],
                    votes, last[w], rngs[w], neg_rngs[w],
                )
        # join_votes: no lost updates on partition boundaries.
        for c in range(p.classes):
            assert votes[c] == sum(last[w][c] for w in range(self.threads))
        self.epochs_run += 1

    def _step(self, w, lits, words, y, votes, last, rng, neg_rng):
        p = self.params
        n, s, t = p.ta_states, p.specificity, p.threshold
        part = self.parts[w]
        neg = None
        if p.classes > 1:
            neg = neg_rng.index(p.classes - 1)
            if neg >= y:
                neg += 1
        fired_all = None
        if self.indexes is not None:
            fired_all = self.indexes[w].fired_flags(lits)
        targets = [(y, True)]
        if neg is not None:
            targets.append((neg, False))
        for class_, positive in targets:
            # Evaluate this worker's clauses of the touched class and
            # publish the fresh partial sum (stale-vote refresh).
            contrib = 0
            fired = {}
            for k, oc in enumerate(part):
                if oc.class_ != class_:
                    continue
                f = (
                    fired_all[k]
                    if fired_all is not None
                    else oc.state.fires_packed(words)
                )
                fired[k] = f
                if f:
                    contrib += 1 if oc.slot % 2 == 0 else -1
            votes[class_] += contrib - last[class_]
            last[class_] = contrib
            sum_ = max(-t, min(t, votes[class_]))
            if positive:
                p_update = (t - sum_) / (2 * t)
            else:
                p_update = (t + sum_) / (2 * t)
            for k, oc in enumerate(part):
                if oc.class_ != class_:
                    continue
                if not rng.chance(p_update):
                    continue
                f = fired[k]
                old = (
                    list(oc.state.include_words)
                    if self.indexes is not None
                    else None
                )
                touched = False
                if positive == (oc.slot % 2 == 0):
                    type_i(oc.state, lits, f, n, s, rng)
                    touched = True
                elif f:
                    type_ii(oc.state, lits, n)
                    touched = True
                if touched and old is not None:
                    self.indexes[w].apply_diff(k, old, oc.state.include_words)

    def train(self, features, labels, epochs):
        for _ in range(epochs):
            self.epoch(features, labels)
        return self.export()

    def export(self):
        n = self.params.ta_states
        masks = [
            [None] * self.params.clauses for _ in range(self.params.classes)
        ]
        for part in self.parts:
            for oc in part:
                masks[oc.class_][oc.slot] = oc.state.include_mask(n)
        return masks

    def coherent(self):
        n = self.params.ta_states
        if not all(oc.state.coherent(n) for part in self.parts for oc in part):
            return False
        if self.indexes is not None:
            return all(
                idx.coherent([oc.state for oc in part])
                for idx, part in zip(self.indexes, self.parts)
            )
        return True

    def states_in_bounds(self):
        n = self.params.ta_states
        return all(
            1 <= st <= 2 * n
            for part in self.parts
            for oc in part
            for st in oc.state.states
        )


class AsyncCoTmTrainer:
    """Clause-parallel coalesced trainer, deterministic schedule.

    Weight column ``j`` travels with clause ``j``: the owning worker is
    the only writer of both, so feedback stays lock-free. Unlike the
    multi-class step, every class update touches *all* owned clauses,
    and the reference trainer re-evaluates clause outputs per class
    update (the positive update's feedback changes the shared clauses
    before the negative update) — so the sweep runs once per class
    update here, not once per sample.
    """

    def __init__(self, params, seed, threads, engine="packed"):
        assert engine in ("packed", "indexed"), engine
        assert threads >= 1
        self.params = params
        self.seed = seed
        self.threads = threads
        self.engine = engine
        self.epochs_run = 0
        n = params.ta_states
        init_rng = SplitMix64(seed)
        self.parts = [[] for _ in range(threads)]
        for j in range(params.clauses):
            st = ClauseState.init(params.literals(), n, init_rng)
            weights = [
                1 if (j + k) % 2 == 0 else -1 for k in range(params.classes)
            ]
            self.parts[j % threads].append(_Owned(None, j, st, weights))
        self.indexes = None
        if engine == "indexed":
            self.indexes = [
                TrainIndex([oc.state for oc in part], n, params.literals())
                for part in self.parts
            ]

    def epoch(self, features, labels):
        p = self.params
        e = self.epochs_run
        order = list(range(len(features)))
        SplitMix64(stream_seed(self.seed, e, LANE_ORDER)).shuffle(order)
        votes = [0] * p.classes
        last = [[0] * p.classes for _ in range(self.threads)]
        rngs = [
            SplitMix64(stream_seed(self.seed, e, LANE_WORKER0 + w))
            for w in range(self.threads)
        ]
        neg_rngs = [
            SplitMix64(stream_seed(self.seed, e, LANE_NEG))
            for _ in range(self.threads)
        ]
        lits_all = [make_literals(x) for x in features]
        words_all = [pack_literals(x) for x in features]
        for i in order:
            for w in range(self.threads):
                self._step(
                    w, lits_all[i], words_all[i], labels[i],
                    votes, last[w], rngs[w], neg_rngs[w],
                )
        for c in range(p.classes):
            assert votes[c] == sum(last[w][c] for w in range(self.threads))
        self.epochs_run += 1

    def _step(self, w, lits, words, y, votes, last, rng, neg_rng):
        p = self.params
        n, s, t = p.ta_states, p.specificity, p.threshold
        wmax = p.max_weight
        part = self.parts[w]
        neg = None
        if p.classes > 1:
            neg = neg_rng.index(p.classes - 1)
            if neg >= y:
                neg += 1
        targets = [(y, True)]
        if neg is not None:
            targets.append((neg, False))
        for class_, positive in targets:
            if self.indexes is not None:
                fired = self.indexes[w].fired_flags(lits)
            else:
                fired = [oc.state.fires_packed(words) for oc in part]
            contrib = sum(
                oc.weights[class_]
                for k, oc in enumerate(part)
                if fired[k]
            )
            votes[class_] += contrib - last[class_]
            last[class_] = contrib
            sum_ = max(-t, min(t, votes[class_]))
            if positive:
                p_update = (t - sum_) / (2 * t)
            else:
                p_update = (t + sum_) / (2 * t)
            for k, oc in enumerate(part):
                if not rng.chance(p_update):
                    continue
                f = fired[k]
                wgt = oc.weights[class_]  # pre-update sign decides role
                old = (
                    list(oc.state.include_words)
                    if self.indexes is not None
                    else None
                )
                touched = False
                if positive:
                    if f:
                        oc.weights[class_] = min(wgt + 1, wmax)
                        if wgt >= 0:
                            type_i(oc.state, lits, True, n, s, rng)
                        else:
                            type_ii(oc.state, lits, n)
                        touched = True
                    elif wgt >= 0:
                        type_i(oc.state, lits, False, n, s, rng)
                        touched = True
                elif f:
                    oc.weights[class_] = max(wgt - 1, -wmax)
                    if wgt > 0:
                        type_ii(oc.state, lits, n)
                    else:
                        type_i(oc.state, lits, True, n, s, rng)
                    touched = True
                elif wgt < 0:
                    type_i(oc.state, lits, False, n, s, rng)
                    touched = True
                if touched and old is not None:
                    self.indexes[w].apply_diff(k, old, oc.state.include_words)

    def train(self, features, labels, epochs):
        for _ in range(epochs):
            self.epoch(features, labels)
        return self.export()

    def export(self):
        n = self.params.ta_states
        masks = [None] * self.params.clauses
        weights = [
            [0] * self.params.clauses for _ in range(self.params.classes)
        ]
        for part in self.parts:
            for oc in part:
                masks[oc.slot] = oc.state.include_mask(n)
                for k in range(self.params.classes):
                    weights[k][oc.slot] = oc.weights[k]
        return masks, weights

    def coherent(self):
        n = self.params.ta_states
        if not all(oc.state.coherent(n) for part in self.parts for oc in part):
            return False
        if self.indexes is not None:
            return all(
                idx.coherent([oc.state for oc in part])
                for idx, part in zip(self.indexes, self.parts)
            )
        return True

    def states_in_bounds(self):
        n = self.params.ta_states
        return all(
            1 <= st <= 2 * n
            for part in self.parts
            for oc in part
            for st in oc.state.states
        )
