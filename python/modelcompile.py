"""Python mirror of the load-time model compilation pass.

Mirrors ``rust/src/tm/compile.rs`` algorithm-for-algorithm so the
prune/reorder/plan/stats logic can be validated (hand-worked oracles,
cross-language golden vectors, randomized differential tests against
the direct evaluator) on CI images that carry no Rust toolchain — the
same arrangement as ``invindex.py`` / ``compressed.py`` for the serving
engines. Any change to the Rust compile pass must be replayed here and
in both golden-vector test suites.

Algorithm (arXiv 2510.15653, model-specialized inference)
---------------------------------------------------------
Trained models, not engines, decide the fast representation: the
compiler turns a trained model into a compiled artifact every engine
family builds from, with four products:

1. **Dead-clause elimination** — an *all-exclude* clause never fires at
   inference, and a *contradictory* clause (including both ``x_i`` and
   ``not x_i``) can never see all its literals satisfied because
   exactly one of each interleaved pair is set per sample. Both
   contribute exactly 0 to every class sum, so pruning is exact.
2. **Fire-probability clause reordering** (mode ``"full"``) over an
   optional calibration batch: descending fire count, ties broken by
   ascending source clause id — fully deterministic, output-invariant.
3. **A per-clause execution plan** (``"skip"`` vs ``"sweep"``) from the
   clause's include-word density, by the same rule as
   ``bitpack::prefers_lane_sweep``.
4. **Compile-time stats** (post-prune density over live clauses,
   postings, clause-length histogram) — the ``auto-*`` selection input.

The multiclass vote polarity is the **source** index parity (Eq. 1),
frozen into the artifact so pruning/reordering cannot skew sums; CoTM
weight columns follow their clause through prune + reorder the same
way.
"""

from invindex import make_literals
from packedtrain import SplitMix64

# Clause-length histogram buckets: bucket min(len * 8 // 2F, 7).
HIST_BUCKETS = 8

# The shared plan rule (bitpack.rs: LANE_SWEEP_MIN_NONZERO): lane-sweep
# iff nonzero_words >= 8 and 2 * nonzero_words >= words.
LANE_SWEEP_MIN_NONZERO = 8
WORD_BITS = 64

MODES = ("off", "prune", "full")
PLANS = ("skip", "sweep")


def prefers_lane_sweep(nonzero_words, words):
    """Mirror of ``bitpack::prefers_lane_sweep``."""
    return (
        nonzero_words >= LANE_SWEEP_MIN_NONZERO and 2 * nonzero_words >= words
    )


def words_for(bits):
    return (bits + WORD_BITS - 1) // WORD_BITS


def dead_reason(mask):
    """``"all_exclude"``, ``"contradictory"`` or ``None`` — all-exclude
    takes precedence, like ``compile::dead_reason``."""
    if not any(mask):
        return "all_exclude"
    for i in range(0, len(mask) - 1, 2):
        if mask[i] and mask[i + 1]:
            return "contradictory"
    return None


def plan_for_mask(mask):
    """Execution plan from include-word density (``plan_for_mask``)."""
    words = words_for(len(mask))
    nonzero = sum(
        1
        for w in range(words)
        if any(mask[w * WORD_BITS : (w + 1) * WORD_BITS])
    )
    return "sweep" if prefers_lane_sweep(nonzero, words) else "skip"


def evaluate_mask(mask, lits):
    """``ClauseMask::evaluate``: empty clauses output 0 at inference;
    otherwise AND over the included literals."""
    if not any(mask):
        return False
    return all(lit for inc, lit in zip(mask, lits) if inc)


class CompiledClause:
    """One live clause in execution order: include mask, original
    (source) clause id, execution plan."""

    def __init__(self, mask, source, plan):
        self.mask = mask
        self.source = source
        self.plan = plan


class CompileStats:
    """Mirror of ``compile::CompileStats`` — an intrinsic property of
    the model, identical whatever mode ran."""

    def __init__(self):
        self.total_clauses = 0
        self.live_clauses = 0
        self.dead_all_exclude = 0
        self.dead_contradictory = 0
        self.postings = 0
        self.density = 0.0
        self.lane_sweep_clauses = 0
        self.skip_list_clauses = 0
        self.length_histogram = [0] * HIST_BUCKETS

    @classmethod
    def from_masks(cls, literals, masks):
        s = cls()
        for mask in masks:
            s.total_clauses += 1
            reason = dead_reason(mask)
            if reason == "all_exclude":
                s.dead_all_exclude += 1
            elif reason == "contradictory":
                s.dead_contradictory += 1
            else:
                s.live_clauses += 1
                length = sum(1 for b in mask if b)
                s.postings += length
                if plan_for_mask(mask) == "sweep":
                    s.lane_sweep_clauses += 1
                else:
                    s.skip_list_clauses += 1
                bucket = (
                    0
                    if literals == 0
                    else min(length * HIST_BUCKETS // literals, HIST_BUCKETS - 1)
                )
                s.length_histogram[bucket] += 1
        if s.live_clauses > 0 and literals > 0:
            s.density = s.postings / (s.live_clauses * literals)
        return s


class CompiledMulticlass:
    """``[class] -> live clauses`` in execution order, with explicit
    per-clause vote polarity frozen from the source index parity."""

    def __init__(self, features, classes, polarities, stats, mode):
        self.features = features
        self.classes = classes
        self.polarities = polarities
        self.stats = stats
        self.mode = mode

    def source_orders(self):
        """Per-class execution order as source ids — the cross-language
        reorder golden."""
        return [[cc.source for cc in cls] for cls in self.classes]

    def class_sums(self, sample):
        """Direct walk of the compiled artifact (mask evaluate +
        explicit polarity) — the bit-identity reference."""
        lits = make_literals(sample)
        sums = []
        for cls, pols in zip(self.classes, self.polarities):
            s = 0
            for cc, pol in zip(cls, pols):
                if evaluate_mask(cc.mask, lits):
                    s += pol
            sums.append(s)
        return sums


class CompiledCotm:
    """The shared live clause pool in execution order plus explicit
    per-clause weight columns (permuted in lockstep)."""

    def __init__(self, features, classes, clauses, weight_cols, stats, mode):
        self.features = features
        self.classes = classes
        self.clauses = clauses
        self.weight_cols = weight_cols
        self.stats = stats
        self.mode = mode

    def source_order(self):
        return [cc.source for cc in self.clauses]

    def class_sums(self, sample):
        lits = make_literals(sample)
        sums = [0] * self.classes
        for cc, col in zip(self.clauses, self.weight_cols):
            if evaluate_mask(cc.mask, lits):
                for k, w in enumerate(col):
                    sums[k] += w
        return sums


class ModelCompiler:
    """Mirror of ``compile::ModelCompiler``: construct with a mode,
    optionally add a calibration batch, then compile."""

    def __init__(self, mode="prune"):
        if mode not in MODES:
            raise ValueError(f"unknown compile mode {mode!r}")
        self.mode = mode
        self.calibration = None

    def with_calibration(self, rows):
        self.calibration = rows
        return self

    def with_synthetic_calibration(self, features, samples, seed):
        """Deterministic synthetic batch — the same SplitMix64
        ``next_bool`` stream the Rust server draws for
        ``compile = "full"``."""
        rng = SplitMix64(seed)
        self.calibration = [
            [rng.next_bool() for _ in range(features)] for _ in range(samples)
        ]
        return self

    def _check_calibration(self, features):
        if self.calibration is not None:
            for i, row in enumerate(self.calibration):
                if len(row) != features:
                    raise ValueError(
                        f"calibration row {i} width {len(row)} != F={features}"
                    )

    def _fire_counts(self, clauses):
        if self.calibration is None:
            return None
        lits = [make_literals(r) for r in self.calibration]
        return [
            sum(1 for l in lits if evaluate_mask(cc.mask, l)) for cc in clauses
        ]

    def _reorder(self, clauses):
        """Descending fire count, ties by ascending source id — the
        deterministic key of ``ModelCompiler::reorder``."""
        if self.mode != "full":
            return clauses
        fires = self._fire_counts(clauses)
        if fires is None:
            return clauses
        order = sorted(
            range(len(clauses)), key=lambda i: (-fires[i], clauses[i].source)
        )
        return [clauses[i] for i in order]

    def _emit(self, masks):
        """Live clauses in model order (``"off"`` keeps dead ones)."""
        return [
            CompiledClause(list(mask), j, plan_for_mask(mask))
            for j, mask in enumerate(masks)
            if self.mode == "off" or dead_reason(mask) is None
        ]

    def compile_multiclass(self, clauses):
        # clauses: [K][C][2F] include masks.
        if not clauses or not clauses[0]:
            raise ValueError("degenerate shape")
        if len(clauses[0]) % 2 != 0:
            raise ValueError("multiclass clause count must be even")
        features = len(clauses[0][0]) // 2
        self._check_calibration(features)
        out_classes = []
        polarities = []
        for cls in clauses:
            for mask in cls:
                if len(mask) != 2 * features:
                    raise ValueError("mask width != 2F")
            emitted = self._reorder(self._emit(cls))
            out_classes.append(emitted)
            # Polarity is the *source* index parity (Eq. 1), frozen
            # into the artifact so prune/reorder cannot skew sums.
            polarities.append(
                [1 if cc.source % 2 == 0 else -1 for cc in emitted]
            )
        stats = CompileStats.from_masks(
            2 * features, [m for cls in clauses for m in cls]
        )
        return CompiledMulticlass(
            features, out_classes, polarities, stats, self.mode
        )

    def compile_cotm(self, clauses, weights):
        # clauses: [C][2F]; weights: [K][C].
        if not clauses:
            raise ValueError("degenerate shape")
        features = len(clauses[0]) // 2
        for mask in clauses:
            if len(mask) != 2 * features:
                raise ValueError("mask width != 2F")
        for row in weights:
            if len(row) != len(clauses):
                raise ValueError("weight row width != C")
        self._check_calibration(features)
        emitted = self._reorder(self._emit(clauses))
        # Weight columns follow their clause through prune + reorder.
        weight_cols = [[row[cc.source] for row in weights] for cc in emitted]
        stats = CompileStats.from_masks(2 * features, clauses)
        return CompiledCotm(
            features, len(weights), emitted, weight_cols, stats, self.mode
        )
