"""Python mirror of the packed-evaluation TM training engine.

Mirrors ``rust/src/tm/trainer_engine.rs`` + ``tm/train.rs`` +
``tm/cotm_train.rs`` algorithm-for-algorithm — including the SplitMix64
RNG stream (``util/rng.rs``) — so the PR's headline invariant can be
validated on CI images that carry no Rust toolchain, the same
arrangement as ``hashring.py`` and ``invindex.py`` for earlier tiers:

    For the same seed, the packed-evaluation trainer produces a model
    **bit-identical** to the reference per-literal trainer.

The invariant holds because the packed path changes only *how* clause
firing is computed, never *what* fires or the RNG consumption order:

* TA counter state stays per-literal in ``1..=2N`` (feedback semantics
  untouched); each clause additionally maintains a packed include mask
  (``u64`` words) updated incrementally, only when a TA crosses the
  N/N+1 include boundary;
* ``class_sum`` / ``clause_fires`` go through the packed evaluator with
  **training-time empty-clause-fires semantics**: an all-exclude mask
  has all-zero words, so the word-AND reduction is vacuously true and
  the clause fires — exactly the reference trainer's convention (an
  empty clause must fire to receive Type I feedback and grow), and the
  opposite of the inference convention in ``bitpack.rs``;
* evaluation consumes no randomness, so the Bernoulli/shuffle stream is
  byte-for-byte the stream the reference trainer consumes.

All float arithmetic here (``(s-1)/s``, ``(T-sum)/2T``, the 53-bit
``next_f64``) is IEEE-754 double in both languages, so the ``chance``
comparisons are exact mirrors, not approximations. Any change to the
Rust trainer algorithm must be replayed here and in the shared golden
vectors of ``tests/test_packedtrain.py`` / ``trainer_engine.rs``.
"""

MASK64 = (1 << 64) - 1
WORD_BITS = 64


class SplitMix64:
    """Exact mirror of ``rust/src/util/rng.rs`` (same stream per seed)."""

    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def next_below(self, bound):
        """Lemire multiply-shift rejection, as in the Rust source."""
        assert bound > 0
        x = self.next_u64()
        m = x * bound
        lo = m & MASK64
        if lo < bound:
            t = ((1 << 64) - bound) % bound
            while lo < t:
                x = self.next_u64()
                m = x * bound
                lo = m & MASK64
        return m >> 64

    def index(self, bound):
        return self.next_below(bound)

    def next_f64(self):
        # (x >> 11) has <= 53 bits, so the float conversion and the
        # multiply by 2^-53 are both exact — identical to Rust.
        return float(self.next_u64() >> 11) * (2.0 ** -53)

    def chance(self, p):
        return self.next_f64() < p

    def next_bool(self):
        return self.next_u64() & 1 == 1

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.index(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


# ---------------------------------------------------------------------
# Packed words (bitpack.rs mirror, little-endian bit order).
# ---------------------------------------------------------------------

def words_for(bits):
    return (bits + WORD_BITS - 1) // WORD_BITS


def pack_bools(bits):
    words = [0] * words_for(len(bits))
    for i, b in enumerate(bits):
        if b:
            words[i // WORD_BITS] |= 1 << (i % WORD_BITS)
    return words


def pack_literals(features):
    """Interleaved literals (lit[2i]=x_i, lit[2i+1]=not x_i), packed."""
    words = [0] * words_for(2 * len(features))
    for i, f in enumerate(features):
        pos = 2 * i + (0 if f else 1)
        words[pos // WORD_BITS] |= 1 << (pos % WORD_BITS)
    return words


def make_literals(features):
    lits = []
    for f in features:
        lits.append(bool(f))
        lits.append(not f)
    return lits


# ---------------------------------------------------------------------
# Clause state: per-literal TA counters + incremental packed mask
# (trainer_engine.rs mirror).
# ---------------------------------------------------------------------

class ClauseState:
    """TA states in ``1..=2N`` plus an incrementally-updated packed
    include mask (``state > N`` = include)."""

    def __init__(self, states, n):
        self.states = list(states)
        include = [st > n for st in self.states]
        self.include_words = pack_bools(include)
        self.included = sum(include)

    @classmethod
    def init(cls, literals, n, rng):
        # Same draw order as the reference trainer's init: one
        # next_bool per literal, N or N+1.
        return cls([n if rng.next_bool() else n + 1 for _ in range(literals)], n)

    def set_ta(self, l, st, n):
        """Write a TA state, updating the packed mask only when the
        N/N+1 include boundary is crossed."""
        was = self.states[l] > n
        now = st > n
        self.states[l] = st
        if was != now:
            w, bit = l // WORD_BITS, 1 << (l % WORD_BITS)
            if now:
                self.include_words[w] |= bit
                self.included += 1
            else:
                self.include_words[w] &= ~bit
                self.included -= 1

    def fires_packed(self, literal_words):
        """Training-time packed evaluation: empty clause (all-zero
        words) fires — the AND-of-nothing reading, *unlike* inference."""
        return all(
            inc & ~lw & MASK64 == 0
            for inc, lw in zip(self.include_words, literal_words)
        )

    def fires_reference(self, lits, n):
        """Training-time per-literal evaluation (the reference path)."""
        return all(st <= n or lit for st, lit in zip(self.states, lits))

    def fires(self, lits, literal_words, n):
        if literal_words is not None:
            return self.fires_packed(literal_words)
        return self.fires_reference(lits, n)

    def recomputed_words(self, n):
        return pack_bools([st > n for st in self.states])

    def coherent(self, n):
        """The incremental mask must always equal a from-scratch pack."""
        return (
            self.include_words == self.recomputed_words(n)
            and self.included == sum(1 for st in self.states if st > n)
        )

    def include_mask(self, n):
        return [st > n for st in self.states]


def type_i(clause, lits, fired, n, s, rng):
    """Type I feedback (recognise). Consumes exactly one Bernoulli draw
    per literal, in literal order — the stream contract both trainers
    and both engines share."""
    p_forget = 1.0 / s
    p_reinforce = (s - 1.0) / s
    for l, lit in enumerate(lits):
        st = clause.states[l]
        if fired and lit:
            if rng.chance(p_reinforce) and st < 2 * n:
                clause.set_ta(l, st + 1, n)
        elif rng.chance(p_forget) and st > 1:
            clause.set_ta(l, st - 1, n)


def type_ii(clause, lits, n):
    """Type II feedback (reject): include 0-literals. Consumes no RNG."""
    for l, lit in enumerate(lits):
        st = clause.states[l]
        if not lit and st <= n:
            clause.set_ta(l, st + 1, n)


# ---------------------------------------------------------------------
# Trainers (train.rs / cotm_train.rs mirrors). ``engine`` is
# "reference" or "packed"; both must yield identical models per seed.
# ---------------------------------------------------------------------

class TmParams:
    def __init__(self, features, clauses, classes, ta_states, threshold,
                 specificity, max_weight=7):
        self.features = features
        self.clauses = clauses
        self.classes = classes
        self.ta_states = ta_states
        self.threshold = threshold
        self.specificity = specificity
        self.max_weight = max_weight

    def literals(self):
        return 2 * self.features


class MultiClassTrainer:
    def __init__(self, params, seed, engine="packed"):
        assert engine in ("reference", "packed"), engine
        assert params.clauses % 2 == 0
        self.params = params
        self.engine = engine
        self.rng = SplitMix64(seed)
        n = params.ta_states
        self.states = [
            [ClauseState.init(params.literals(), n, self.rng)
             for _ in range(params.clauses)]
            for _ in range(params.classes)
        ]

    def _words(self, features):
        return pack_literals(features) if self.engine == "packed" else None

    def class_sum(self, class_, lits, words):
        n = self.params.ta_states
        total = 0
        for j, cl in enumerate(self.states[class_]):
            out = 1 if cl.fires(lits, words, n) else 0
            total += out if j % 2 == 0 else -out
        return total

    def update_class(self, class_, lits, words, positive):
        t = self.params.threshold
        sum_ = max(-t, min(t, self.class_sum(class_, lits, words)))
        if positive:
            p_update = (t - sum_) / (2 * t)
        else:
            p_update = (t + sum_) / (2 * t)
        n = self.params.ta_states
        s = self.params.specificity
        for j in range(self.params.clauses):
            if not self.rng.chance(p_update):
                continue
            cl = self.states[class_][j]
            fired = cl.fires(lits, words, n)
            positive_clause = j % 2 == 0
            if positive == positive_clause:
                type_i(cl, lits, fired, n, s, self.rng)
            elif fired:
                type_ii(cl, lits, n)

    def epoch(self, features, labels):
        order = list(range(len(features)))
        self.rng.shuffle(order)
        for i in order:
            lits = make_literals(features[i])
            words = self._words(features[i])
            y = labels[i]
            self.update_class(y, lits, words, True)
            if self.params.classes > 1:
                neg = self.rng.index(self.params.classes - 1)
                if neg >= y:
                    neg += 1
                self.update_class(neg, lits, words, False)

    def train(self, features, labels, epochs):
        for _ in range(epochs):
            self.epoch(features, labels)
        return self.export()

    def export(self):
        n = self.params.ta_states
        return [[cl.include_mask(n) for cl in cls] for cls in self.states]

    def coherent(self):
        n = self.params.ta_states
        return all(cl.coherent(n) for cls in self.states for cl in cls)

    def states_in_bounds(self):
        n = self.params.ta_states
        return all(
            1 <= st <= 2 * n
            for cls in self.states for cl in cls for st in cl.states
        )


class CoTmTrainer:
    def __init__(self, params, seed, engine="packed"):
        assert engine in ("reference", "packed"), engine
        self.params = params
        self.engine = engine
        self.rng = SplitMix64(seed)
        n = params.ta_states
        self.states = [
            ClauseState.init(params.literals(), n, self.rng)
            for _ in range(params.clauses)
        ]
        # Weights start at +/-1 alternating per class to break symmetry.
        self.weights = [
            [1 if (j + k) % 2 == 0 else -1 for j in range(params.clauses)]
            for k in range(params.classes)
        ]

    def _words(self, features):
        return pack_literals(features) if self.engine == "packed" else None

    def clause_outputs(self, lits, words):
        n = self.params.ta_states
        return [cl.fires(lits, words, n) for cl in self.states]

    def class_sum(self, class_, outputs):
        return sum(
            w for w, c in zip(self.weights[class_], outputs) if c
        )

    def update_class(self, class_, lits, words, positive):
        t = self.params.threshold
        outputs = self.clause_outputs(lits, words)
        sum_ = max(-t, min(t, self.class_sum(class_, outputs)))
        if positive:
            p_update = (t - sum_) / (2 * t)
        else:
            p_update = (t + sum_) / (2 * t)
        n = self.params.ta_states
        s = self.params.specificity
        wmax = self.params.max_weight
        for j in range(self.params.clauses):
            if not self.rng.chance(p_update):
                continue
            fired = outputs[j]
            w = self.weights[class_][j]  # pre-update sign decides role
            cl = self.states[j]
            if positive:
                if fired:
                    self.weights[class_][j] = min(w + 1, wmax)
                    if w >= 0:
                        type_i(cl, lits, True, n, s, self.rng)
                    else:
                        type_ii(cl, lits, n)
                elif w >= 0:
                    type_i(cl, lits, False, n, s, self.rng)
            elif fired:
                self.weights[class_][j] = max(w - 1, -wmax)
                if w > 0:
                    type_ii(cl, lits, n)
                else:
                    type_i(cl, lits, True, n, s, self.rng)
            elif w < 0:
                type_i(cl, lits, False, n, s, self.rng)

    def epoch(self, features, labels):
        order = list(range(len(features)))
        self.rng.shuffle(order)
        for i in order:
            lits = make_literals(features[i])
            words = self._words(features[i])
            y = labels[i]
            self.update_class(y, lits, words, True)
            if self.params.classes > 1:
                neg = self.rng.index(self.params.classes - 1)
                if neg >= y:
                    neg += 1
                self.update_class(neg, lits, words, False)

    def train(self, features, labels, epochs):
        for _ in range(epochs):
            self.epoch(features, labels)
        return self.export()

    def export(self):
        n = self.params.ta_states
        masks = [cl.include_mask(n) for cl in self.states]
        return masks, [row[:] for row in self.weights]

    def coherent(self):
        n = self.params.ta_states
        return all(cl.coherent(n) for cl in self.states)

    def states_in_bounds(self):
        n = self.params.ta_states
        return all(
            1 <= st <= 2 * n for cl in self.states for st in cl.states
        )
