"""Mirror of the tiled bit-sliced batch layout (rust/src/tm/bitpack.rs).

The Rust serving engines evaluate batches through cache-blocked tiles:
samples are split into 64-wide *blocks* (bit ``s % 64`` of a block word
holds sample ``s``), blocks into tiles of ``TILE_BLOCKS``; within a tile
the layout is literal-major, so literal ``l``'s lane words for the
tile's blocks are contiguous and one SIMD op covers 4-8 blocks.
Evaluation is clause-major within a tile, samples-block-major across
tiles.

This module mirrors the *layout math* (word indexing, tile geometry,
valid masks) and the tile evaluator bit-for-bit, so toolchain-less CI
images can validate the tiling even though they cannot compile the Rust
lane kernels. The golden vectors in ``tests/test_simdtile.py`` are
asserted identically in ``bitpack.rs``; if either side's layout drifts,
both suites fail.

Word index of (block ``blk``, literal ``l``)::

    stride = min(blocks, TILE_BLOCKS)
    word(blk, l) = data[(blk // stride) * 2F * stride   # tile base
                        + l * stride                    # literal lane
                        + blk % stride]                 # block in tile

Plain Python ints stand in for ``u64`` (masked to 64 bits on write).
"""

WORD_BITS = 64
TILE_BLOCKS = 8
_MASK64 = (1 << 64) - 1


def words_for(bits):
    """Number of 64-bit words needed to hold ``bits`` bits."""
    return (bits + WORD_BITS - 1) // WORD_BITS


def tile_geometry(samples):
    """``(blocks, stride, tiles)`` for a batch of ``samples`` samples."""
    blocks = words_for(max(samples, 1))
    stride = min(blocks, TILE_BLOCKS)
    tiles = (blocks + stride - 1) // stride
    return blocks, stride, tiles


def pack_literals(features):
    """One sample's interleaved literals as packed words
    (``lit[2i] = x_i``, ``lit[2i+1] = not x_i``)."""
    words = [0] * words_for(2 * len(features))
    for i, f in enumerate(features):
        pos = 2 * i + (0 if f else 1)
        words[pos // WORD_BITS] |= 1 << (pos % WORD_BITS)
    return words


class TiledBatch:
    """Mirror of ``BitSlicedBatch``: the tiled bit-sliced transpose."""

    def __init__(self, rows, features):
        for row in rows:
            if len(row) != features:
                raise ValueError("batch row width mismatch")
        self.features = features
        self.samples = len(rows)
        self.blocks, self.stride, self.tiles = tile_geometry(self.samples)
        lits = 2 * features
        self.data = [0] * (self.tiles * lits * self.stride)
        for s, row in enumerate(rows):
            blk = s // WORD_BITS
            bit = 1 << (s % WORD_BITS)
            base = (blk // self.stride) * lits * self.stride + blk % self.stride
            for i, f in enumerate(row):
                lit = 2 * i + (0 if f else 1)
                self.data[base + lit * self.stride] |= bit

    def tile_blocks(self, t):
        """Blocks actually present in tile ``t``."""
        return min(self.stride, self.blocks - t * self.stride)

    def lit_lane(self, t, l):
        """The contiguous lane words of literal ``l`` in tile ``t``."""
        base = (t * 2 * self.features + l) * self.stride
        return self.data[base : base + self.tile_blocks(t)]

    def lit_word(self, blk, l):
        """One literal's word for one global block index."""
        t = blk // self.stride
        return self.data[
            (t * 2 * self.features + l) * self.stride + blk % self.stride
        ]

    def valid_mask(self, blk):
        """Mask of valid sample bits in block ``blk``."""
        used = self.samples - blk * WORD_BITS
        if used >= WORD_BITS:
            return _MASK64
        return (1 << used) - 1


def evaluate_tile(batch, literals, t):
    """Clause-output words for tile ``t`` of a clause including the
    given sorted literal indices — the lane evaluator's semantics:
    all-ones accumulator, AND each literal's lane, early-exit when every
    lane is dead; an empty clause outputs all zeros (the inference
    convention)."""
    tb = batch.tile_blocks(t)
    if not literals:
        return [0] * tb
    acc = [_MASK64] * tb
    for l in literals:
        lane = batch.lit_lane(t, l)
        any_alive = 0
        for j in range(tb):
            acc[j] &= lane[j]
            any_alive |= acc[j]
        if not any_alive:
            return acc
    last = t * batch.stride + tb - 1
    if last + 1 == batch.blocks:
        acc[tb - 1] &= batch.valid_mask(last)
    return acc


def evaluate_block(batch, literals, blk):
    """Single-word reference walk for one global block (mirror of
    ``PackedClause::evaluate_batch``)."""
    if not literals:
        return 0
    acc = _MASK64
    for l in literals:
        acc &= batch.lit_word(blk, l)
        if acc == 0:
            break
    return acc & batch.valid_mask(blk)


def clause_outputs(batch, literals):
    """Per-sample clause outputs through the tile evaluator."""
    out = []
    for t in range(batch.tiles):
        out.extend(evaluate_tile(batch, literals, t))
    return [
        (out[s // WORD_BITS] >> (s % WORD_BITS)) & 1 == 1
        for s in range(batch.samples)
    ]


def ref_clause_output(include, sample):
    """Direct reference: AND over included literals of the interleaved
    literal vector; an empty clause outputs False."""
    lits = []
    for f in sample:
        lits.extend([f, not f])
    included = [lits[l] for l, inc in enumerate(include) if inc]
    if not included:
        return False
    return all(included)


def fnv1a64_words(words):
    """FNV-1a/64 over the words' little-endian bytes — the layout
    fingerprint pinned cross-language in the golden tests."""
    h = 0xCBF29CE484222325
    for w in words:
        for shift in range(0, 64, 8):
            h ^= (w >> shift) & 0xFF
            h = (h * 0x00000100000001B3) & _MASK64
    return h
