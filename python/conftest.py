"""Pytest wiring for the L1/L2 layer.

* Makes `compile` importable regardless of invocation directory
  (`pytest python/tests` from the repo root previously failed with
  `ModuleNotFoundError: compile`).
* The offline CI image has no `hypothesis`; property-based modules are
  skipped with a reason rather than erroring at collection. `test_aot`
  (plain pytest) still runs everywhere JAX is present.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# The static-analysis fixture mini-repos under tests/fixtures/ carry
# files named like test modules (e.g. test_hashring.py) that exist to
# be *lexed* by python/analysis, not imported by pytest.
collect_ignore = ["tests/fixtures"]
if importlib.util.find_spec("hypothesis") is None:
    # Environmental, not a logic failure: these suites need the
    # hypothesis package, which cannot be installed offline.
    collect_ignore += ["tests/test_kernel.py", "tests/test_model.py"]
