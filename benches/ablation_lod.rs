//! Ablation: **LOD on vs off** (DESIGN.md §10) — the paper's claim that
//! LOD compression collapses an exponential delay-path space to
//! logarithmic (§II-C.2) while preserving classification.
//!
//! Sweeps the class-sum range and reports: delay-line stages (hardware
//! cost), worst-case race time, and argmax fidelity of the compressed
//! encoding vs the exact linear encoding.
//!
//! Run: `cargo bench --bench ablation_lod`

use tsetlin_td::sim::{TechParams, Time};
use tsetlin_td::timedomain::lod;
use tsetlin_td::util::{SplitMix64, Table};

fn main() {
    let tech = TechParams::tsmc65_proposed();
    let e = tech.fine_bits;

    let mut t = Table::new(vec![
        "max sum",
        "linear stages",
        "LOD stages",
        "compression",
        "linear worst delay (ns)",
        "LOD worst delay (ns)",
    ]);
    for pow in [4u32, 6, 8, 10, 12, 14] {
        let max_sum = 1u64 << pow;
        let linear_stages = max_sum;
        let lod_stages = lod::lod_stage_count(max_sum, e);
        let tau = tech.tau();
        let linear_delay = Time::fs(max_sum * tau.as_fs());
        let lod_delay = lod::lod_delay(max_sum, e, tau);
        t.row(vec![
            max_sum.to_string(),
            linear_stages.to_string(),
            lod_stages.to_string(),
            format!("{:.0}x", linear_stages as f64 / lod_stages as f64),
            format!("{:.2}", linear_delay.as_ns_f64()),
            format!("{:.2}", lod_delay.as_ns_f64()),
        ]);
    }
    println!("== Ablation: LOD compression vs linear delay encoding ==");
    println!("{}", t.render());

    // Fidelity: fraction of random (S,M) pairs whose pairwise order under
    // the LOD-compressed differential objective matches exact argmax.
    let mut rng = SplitMix64::new(99);
    let mut t2 = Table::new(vec!["sum range", "pairwise order agreement %"]);
    for range in [16u64, 32, 64, 128, 256] {
        let mut agree = 0u64;
        let trials = 20_000u64;
        for _ in 0..trials {
            let (s1, m1) = (rng.next_below(range), rng.next_below(range));
            let (s2, m2) = (rng.next_below(range), rng.next_below(range));
            let exact = (m1 as i64 - s1 as i64).cmp(&(m2 as i64 - s2 as i64));
            let g = |v: u64| lod::lod_delay_units(v, e) as i64;
            let comp = (g(m1) - g(s1)).cmp(&(g(m2) - g(s2)));
            if exact == comp || exact == std::cmp::Ordering::Equal {
                agree += 1;
            }
        }
        t2.row(vec![
            format!("0..{range}"),
            format!("{:.1}", 100.0 * agree as f64 / trials as f64),
        ]);
    }
    println!("== LOD ordering fidelity (the cost of log compression) ==");
    println!("{}", t2.render());
    println!(
        "note: disagreements concentrate where |M−S| is small relative to the\n\
         magnitude scale — the quantisation the paper accepts for log path length."
    );

    // Structural claims.
    assert!(lod::lod_stage_count(1 << 12, e) <= 16);
    assert!((1u64 << 12) / lod::lod_stage_count(1 << 12, e) > 200);
    println!("shape assertions: OK (exponential -> logarithmic path)");
}
