//! Thread-scaling bench for the async clause-parallel trainer — the
//! PR 10 perf-trajectory bench (the multicore counterpart of
//! `train_packed_vs_ref`, which pins the sequential tiers).
//!
//! The async tier's promise is throughput, bought with deliberate
//! nondeterminism (stale relaxed-atomic vote snapshots — see
//! docs/TRAINING.md). A scaling number over a tier that learns a
//! *worse* model would be meaningless, so the statistical
//! accuracy-parity bar is asserted **before** anything is timed: on a
//! seeded blobs problem the async trainer's accuracy must land within
//! epsilon of the packed reference trainer's, and the reference must
//! have actually learned. Only then does the bench time threaded
//! epochs on the large synthetic regime (256 features, 512 clauses,
//! 4 classes) at 1/2/4/8 workers.
//!
//! Target: >=4x ms/epoch speedup at 8 threads over the same tier at 1
//! thread (the 1-thread baseline IS the deterministic schedule, so
//! this is the cost of the schedule going parallel, nothing else).
//!
//! Run: `cargo bench --bench train_async_scaling`

use std::time::Instant;

use tsetlin_td::tm::infer::multiclass_accuracy;
use tsetlin_td::tm::train::train_multiclass_with;
use tsetlin_td::tm::{
    data, train_multiclass_async, AsyncMultiClassTrainer, TmParams, TrainerEngine,
};
use tsetlin_td::util::Table;

/// Same epsilon as `tmtd selfcheck` and the conformance suites.
const PARITY_EPS: f64 = 0.15;

/// Steady-state epochs: converge untimed first (the early epochs are
/// Type I–dominated in every tier), then time.
const CONVERGE_EPOCHS: usize = 3;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

fn time_epochs_ms(reps: usize, mut epoch: impl FnMut()) -> f64 {
    epoch(); // warm-up
    let t0 = Instant::now();
    for _ in 0..reps {
        epoch();
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

/// The accuracy-parity gate. Panics (failing the bench) on a miss —
/// a scaling table over a broken trainer must not be recordable.
fn assert_parity() {
    let p = TmParams {
        features: 20,
        clauses: 10,
        classes: 3,
        ta_states: 32,
        threshold: 8,
        specificity: 3.0,
        max_weight: 5,
    };
    for seed in [1u64, 2, 3] {
        let d = data::prototype_blobs(90, 20, 3, 0.05, seed);
        let m_ref = train_multiclass_with(p.clone(), &d, 10, seed, TrainerEngine::Packed)
            .expect("reference train");
        let m_async =
            train_multiclass_async(p.clone(), &d, 10, seed, 4, false).expect("async train");
        let ra = multiclass_accuracy(&m_ref, &d.features, &d.labels);
        let aa = multiclass_accuracy(&m_async, &d.features, &d.labels);
        assert!(ra > 0.6, "seed {seed}: reference tier failed to learn (acc {ra})");
        assert!(
            (ra - aa).abs() <= PARITY_EPS,
            "seed {seed}: async accuracy {aa} vs reference {ra} exceeds eps {PARITY_EPS}"
        );
        println!("  parity seed {seed}: reference {ra:.3}, async {aa:.3}");
    }
}

fn main() {
    println!("== async clause-parallel trainer: thread scaling ==");
    println!("accuracy-parity gate (eps {PARITY_EPS}, 3 seeds) before timing:");
    assert_parity();

    // The large synthetic regime: 256 features, 512 clauses, 4 classes.
    let (bf, bc, bk) = (256usize, 512usize, 4usize);
    let big = data::prototype_blobs(192, bf, bk, 0.1, 9);
    let big_p = TmParams {
        features: bf,
        clauses: bc,
        classes: bk,
        ta_states: 64,
        threshold: 16,
        specificity: 3.0,
        max_weight: 7,
    };

    let mut table = Table::new(vec![
        "threads",
        "packed ms/epoch",
        "indexed ms/epoch",
        "speedup vs 1",
    ]);
    let mut base_ms = 0.0f64;
    let mut speedup_at_8 = 0.0f64;
    for &threads in &THREAD_SWEEP {
        let mut packed = AsyncMultiClassTrainer::new(big_p.clone(), 5, threads, false)
            .expect("valid params");
        let mut indexed = AsyncMultiClassTrainer::new(big_p.clone(), 5, threads, true)
            .expect("valid params");
        for _ in 0..CONVERGE_EPOCHS {
            packed.epoch(&big.features, &big.labels).expect("epoch");
            indexed.epoch(&big.features, &big.labels).expect("epoch");
        }
        let packed_ms =
            time_epochs_ms(2, || packed.epoch(&big.features, &big.labels).expect("epoch"));
        let indexed_ms =
            time_epochs_ms(2, || indexed.epoch(&big.features, &big.labels).expect("epoch"));
        packed.check_invariants().expect("async invariants");
        indexed.check_invariants().expect("async invariants");
        if threads == 1 {
            base_ms = packed_ms;
        }
        let speedup = base_ms / packed_ms;
        if threads == 8 {
            speedup_at_8 = speedup;
        }
        table.row(vec![
            threads.to_string(),
            format!("{packed_ms:.2}"),
            format!("{indexed_ms:.2}"),
            format!("{speedup:.1}x"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "scaling target (>=4x ms/epoch at 8 threads vs 1 thread): {}",
        if speedup_at_8 >= 4.0 { "PASS" } else { "FAIL" }
    );
}
