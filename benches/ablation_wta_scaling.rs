//! Ablation: **WTA topology scaling** (Table I's trade-off swept wide) —
//! latency, energy and cell count for TBA vs Mesh as the class count
//! grows, including behaviour under close races (metastability stress).
//!
//! Run: `cargo bench --bench ablation_wta_scaling`

use tsetlin_td::sim::energy::TechParams;
use tsetlin_td::sim::{Circuit, Logic, NetId, Time};
use tsetlin_td::util::Table;
use tsetlin_td::wta::{self, analysis, WtaKind};

/// Race with a configurable winner margin; returns (winner==0, decision ps).
fn stress_race(kind: WtaKind, m: usize, margin_ps: u64, tech: &TechParams) -> (bool, f64) {
    let mut c = Circuit::new(tech.clone());
    let races: Vec<NetId> = (0..m)
        .map(|i| c.net_init(format!("race{i}"), Logic::Zero))
        .collect();
    let arb = wta::build(&mut c, kind, "wta", &races);
    c.init_components();
    c.run_to_quiescence().unwrap();
    let t0 = Time::ps(100);
    for (i, &r) in races.iter().enumerate() {
        let d = if i == 0 {
            t0
        } else {
            t0 + Time::ps(margin_ps * i as u64)
        };
        c.drive(r, Logic::One, d);
    }
    let grants = arb.grants.clone();
    let decided = c
        .run_while(Time::ns(10_000), |cc| {
            grants.iter().any(|g| cc.value(*g) == Logic::One)
        })
        .unwrap();
    assert!(decided);
    let winner0 = c.value(grants[0]) == Logic::One;
    (winner0, c.now().since(t0).as_ps_f64())
}

fn main() {
    let tech = TechParams::tsmc65_digital();
    let mut t = Table::new(vec![
        "m",
        "TBA cells",
        "Mesh cells",
        "TBA latency (ps)",
        "Mesh latency (ps)",
        "TBA energy (fJ)",
        "Mesh energy (fJ)",
    ]);
    for m in [2usize, 4, 8, 16, 32] {
        t.row(vec![
            m.to_string(),
            analysis::tba_analysis(m, &tech).cell_count.to_string(),
            analysis::mesh_analysis(m, &tech).cell_count.to_string(),
            format!("{:.0}", analysis::measured_latency(WtaKind::Tba, m, &tech).as_ps_f64()),
            format!("{:.0}", analysis::measured_latency(WtaKind::Mesh, m, &tech).as_ps_f64()),
            format!("{:.1}", analysis::measured_energy_fj(WtaKind::Tba, m, &tech)),
            format!("{:.1}", analysis::measured_energy_fj(WtaKind::Mesh, m, &tech)),
        ]);
    }
    println!("== WTA scaling: tree vs mesh ==");
    println!("{}", t.render());

    // Metastability stress: shrink the margin and watch decisions slow
    // but stay correct (first arrival) and one-hot.
    let mut t2 = Table::new(vec![
        "margin (ps)",
        "TBA correct",
        "TBA decision (ps)",
        "Mesh correct",
        "Mesh decision (ps)",
    ]);
    for margin in [200u64, 50, 20, 8, 2] {
        let (ok_t, lat_t) = stress_race(WtaKind::Tba, 4, margin, &tech);
        let (ok_m, lat_m) = stress_race(WtaKind::Mesh, 4, margin, &tech);
        t2.row(vec![
            margin.to_string(),
            ok_t.to_string(),
            format!("{lat_t:.0}"),
            ok_m.to_string(),
            format!("{lat_m:.0}"),
        ]);
    }
    println!("== Close-race stress (m=4, decreasing winner margin) ==");
    println!("{}", t2.render());

    // Wide-margin races must always pick the first arrival.
    for m in [4usize, 8, 16] {
        assert!(stress_race(WtaKind::Tba, m, 300, &tech).0);
        assert!(stress_race(WtaKind::Mesh, m, 300, &tech).0);
    }
    println!("shape assertions: OK");
}
