//! SIMD lane-width sweep — the perf-trajectory bench for the multi-word
//! evaluation tier (`tm::simd` + the tiled `tm::bitpack` layout).
//!
//! Times the bit-parallel engines at every lane width the host offers —
//! scalar (one `u64` per op, the PR 1 reference walk), portable
//! (4×`u64` unrolled), AVX2 and AVX-512 when detected — on the
//! 256f/512c synthetic model (the regime word-level packing is built
//! for) over a 4096-sample batch, so the cache-blocked tiles actually
//! stream. Prints µs/sample per level and a PASS/FAIL line for the
//! tier's headline target: the portable unrolled baseline at ≥2× the
//! single-word walk. Sanity-asserts bit-identity across all levels
//! before timing anything — a speedup over wrong answers is worthless.
//!
//! Run: `cargo bench --bench simd_vs_scalar`

use std::time::Instant;

use tsetlin_td::tm::simd::{SimdLevel, WordLanes};
use tsetlin_td::tm::{
    BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    MultiClassTmModel, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

/// Time `f` over `reps` repetitions of `samples` samples; µs/sample.
fn time_us_per_sample(samples: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    // One warm-up pass (page in, branch-train), then timed reps.
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / (reps * samples) as f64
}

fn random_mask(rng: &mut SplitMix64, literals: usize, density: f64) -> ClauseMask {
    ClauseMask { include: (0..literals).map(|_| rng.chance(density)).collect() }
}

fn synthetic_multiclass(f: usize, c: usize, k: usize, seed: u64) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = random_mask(&mut rng, 2 * f, 0.08);
        }
    }
    m
}

fn synthetic_cotm(f: usize, c: usize, k: usize, seed: u64) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = CoTmModel::zeroed(p.clone());
    for clause in &mut m.clauses {
        *clause = random_mask(&mut rng, 2 * f, 0.08);
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = rng.next_below(2 * p.max_weight as u64 + 1) as i32 - p.max_weight;
        }
    }
    m
}

fn random_samples(f: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool()).collect()).collect()
}

fn main() {
    println!("== SIMD lane-width sweep (tiled bit-parallel engines) ==");
    let (bf, bc, bk) = (256usize, 512usize, 4usize);
    let batch_n = 4096usize;
    let m = synthetic_multiclass(bf, bc, bk, 7);
    let cm = synthetic_cotm(bf, bc, bk, 11);
    let xs = random_samples(bf, batch_n, 9);

    let levels = SimdLevel::available();
    println!(
        "available lane widths: [{}]; auto resolves to {}",
        levels.iter().map(|l| l.name()).collect::<Vec<_>>().join(", "),
        SimdLevel::detect_best().name()
    );
    for level in SimdLevel::ALL {
        if !levels.contains(&level) {
            println!(
                "note: {} not available on this host (not detected or compiled out)",
                level.name()
            );
        }
    }

    // Sanity first: every level must produce the identical batch.
    let base_mc = BitParallelMulticlass::from_model(&m).expect("valid model");
    let base_co = BitParallelCotm::from_model(&cm).expect("valid model");
    let want_mc = base_mc
        .clone()
        .with_lanes(WordLanes::portable())
        .infer_batch(&xs[..256.min(batch_n)]);
    let want_co = base_co
        .clone()
        .with_lanes(WordLanes::portable())
        .infer_batch(&xs[..256.min(batch_n)]);
    for &level in &levels {
        let lanes = WordLanes::new(level).expect("available level");
        assert_eq!(
            base_mc.clone().with_lanes(lanes).infer_batch(&xs[..256.min(batch_n)]),
            want_mc,
            "multiclass level {} diverged",
            level.name()
        );
        assert_eq!(
            base_co.clone().with_lanes(lanes).infer_batch(&xs[..256.min(batch_n)]),
            want_co,
            "cotm level {} diverged",
            level.name()
        );
    }

    let mut t = Table::new(vec![
        "lane width",
        "lanes",
        "multiclass us/sample",
        "mc speedup vs scalar",
        "cotm us/sample",
        "cotm speedup vs scalar",
    ]);
    let mut mc_us = Vec::new();
    let mut co_us = Vec::new();
    for &level in &levels {
        let lanes = WordLanes::new(level).expect("available level");
        let e_mc = base_mc.clone().with_lanes(lanes);
        let e_co = base_co.clone().with_lanes(lanes);
        let us_mc = time_us_per_sample(batch_n, 3, || {
            std::hint::black_box(e_mc.infer_batch(&xs));
        });
        let us_co = time_us_per_sample(batch_n, 3, || {
            std::hint::black_box(e_co.infer_batch(&xs));
        });
        mc_us.push(us_mc);
        co_us.push(us_co);
        t.row(vec![
            level.name().to_string(),
            format!("x{}", level.lanes()),
            format!("{us_mc:.3}"),
            format!("{:.2}x", mc_us[0] / us_mc),
            format!("{us_co:.3}"),
            format!("{:.2}x", co_us[0] / us_co),
        ]);
    }
    println!(
        "synthetic {bf}f/{bc}c/{bk}k, batch {batch_n} ({} tiles of 8 blocks):",
        batch_n.div_ceil(64).div_ceil(8)
    );
    println!("{}", t.render());

    // Headline target: the portable unrolled baseline >= 2x the
    // single-word scalar walk (levels[0] is always scalar, [1]
    // portable). Wider vector levels are reported above; they can only
    // improve on portable.
    let unrolled_speedup_mc = mc_us[0] / mc_us[1];
    let unrolled_speedup_co = co_us[0] / co_us[1];
    println!(
        "unrolled-vs-single-word: multiclass {unrolled_speedup_mc:.2}x, cotm {unrolled_speedup_co:.2}x"
    );
    println!(
        "lane-tier target (portable unrolled >= 2x single-word on {bf}f/{bc}c): {}",
        if unrolled_speedup_mc >= 2.0 { "PASS" } else { "FAIL" }
    );
}
