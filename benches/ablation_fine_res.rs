//! Ablation: **fine-delay resolution e** (DESIGN.md §10) — how many
//! vernier bits the LOD needs before CoTM classification matches exact
//! argmax on real (Iris-trained) models, and what the extra resolution
//! costs in delay-line stages.
//!
//! Run: `cargo bench --bench ablation_fine_res`

use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::Architecture;
use tsetlin_td::sim::TechParams;
use tsetlin_td::tm::infer::{cotm_class_sums, predict_argmax};
use tsetlin_td::tm::{cotm_train::train_cotm, data, TmParams};
use tsetlin_td::util::Table;
use tsetlin_td::wta::WtaKind;

fn main() {
    let d = data::iris().expect("iris");
    let (tr, _) = d.split(0.8, 42);
    let model = train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();

    let mut t = Table::new(vec![
        "e (fine bits)",
        "fine step (ps)",
        "argmax agreement %",
        "accuracy %",
        "mean race latency (ps)",
    ]);
    let mut agreements = Vec::new();
    for e in [1u32, 2, 3, 4, 6] {
        let mut tech = TechParams::tsmc65_proposed();
        tech.fine_bits = e;
        let mut arch = ProposedCotm::with_tech(model.clone(), WtaKind::Tba, tech.clone())
            .expect("arch");
        let mut agree = 0usize;
        let mut correct = 0usize;
        let mut lat_sum = 0.0;
        for (x, &y) in d.features.iter().zip(&d.labels) {
            let r = arch.infer(x).unwrap();
            let exact = predict_argmax(&cotm_class_sums(&model, x));
            if r.predicted == exact {
                agree += 1;
            }
            if r.predicted == y {
                correct += 1;
            }
            lat_sum += r.latency.as_ps_f64();
        }
        let n = d.len() as f64;
        agreements.push((e, 100.0 * agree as f64 / n));
        t.row(vec![
            e.to_string(),
            format!("{:.2}", tech.cotm_race_corner().fine_step().as_ps_f64()),
            format!("{:.1}", 100.0 * agree as f64 / n),
            format!("{:.1}", 100.0 * correct as f64 / n),
            format!("{:.0}", lat_sum / n),
        ]);
    }
    println!("== Ablation: LOD fine resolution e vs classification fidelity ==");
    println!("{}", t.render());

    // Shape: agreement should be (weakly) non-degrading with e, and the
    // paper's e=4 operating point must reach >= 90% exact-argmax
    // agreement on the trained model.
    let at4 = agreements.iter().find(|(e, _)| *e == 4).unwrap().1;
    assert!(at4 >= 90.0, "e=4 agreement {at4:.1}% < 90%");
    let at1 = agreements.first().unwrap().1;
    assert!(at4 >= at1, "higher resolution must not hurt agreement");
    println!("shape assertions: OK (e=4 agreement {at4:.1}%)");
}
