//! Packed-evaluation trainer vs per-literal reference trainer — the
//! perf-trajectory bench for the training tier (the counterpart of
//! `bitparallel_vs_ref` for inference).
//!
//! Both engines produce bit-identical models for the same seed (the
//! conformance suite enforces it; this bench re-asserts it on a small
//! configuration before timing anything), so the only question is
//! epoch wall-clock. Clause evaluation dominates training cost (class
//! sums are recomputed per update), which is exactly the part the
//! packed engine turns into word-wide ANDs over incrementally-
//! maintained include masks. Target: >=4x epoch speedup on the
//! 256-feature / 512-clause synthetic — the same regime the inference
//! packing is built for.
//!
//! Run: `cargo bench --bench train_packed_vs_ref`

use std::time::Instant;

use tsetlin_td::tm::cotm_train::{train_cotm_with, CoTmTrainer};
use tsetlin_td::tm::train::{train_multiclass_with, MultiClassTrainer};
use tsetlin_td::tm::{data, Dataset, TmParams, TrainerEngine};
use tsetlin_td::util::Table;

/// Time `reps` epochs after one warm-up epoch; ms/epoch.
fn time_epochs_ms(reps: usize, mut epoch: impl FnMut()) -> f64 {
    epoch(); // warm-up (page in, branch-train)
    let t0 = Instant::now();
    for _ in 0..reps {
        epoch();
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

struct Case {
    label: String,
    reference_ms: f64,
    packed_ms: f64,
}

/// Steady-state epoch cost: the first epochs of a fresh trainer are
/// dominated by Type I feedback (one Bernoulli draw per literal —
/// identical work in both engines, and untouchable without changing
/// the RNG stream). After the class sums saturate against ±T the
/// update probability collapses and clause *evaluation* dominates —
/// the regime a long training run spends almost all its time in, and
/// the part the packed engine accelerates. So: converge both trainers
/// identically (untimed), then time epochs.
const CONVERGE_EPOCHS: usize = 3;

fn bench_multiclass(label: &str, p: &TmParams, d: &Dataset, reps: usize) -> Case {
    let mut r = MultiClassTrainer::with_engine(p.clone(), 5, TrainerEngine::Reference)
        .expect("valid params");
    let mut q = MultiClassTrainer::with_engine(p.clone(), 5, TrainerEngine::Packed)
        .expect("valid params");
    for _ in 0..CONVERGE_EPOCHS {
        r.epoch(d);
        q.epoch(d);
    }
    let case = Case {
        label: label.to_string(),
        reference_ms: time_epochs_ms(reps, || r.epoch(d)),
        packed_ms: time_epochs_ms(reps, || q.epoch(d)),
    };
    // Both trainers consumed identical RNG streams, so after equal
    // epoch counts the exported models must still be identical.
    assert_eq!(r.export(), q.export(), "{label}: engines diverged");
    case
}

fn bench_cotm(label: &str, p: &TmParams, d: &Dataset, reps: usize) -> Case {
    let mut r =
        CoTmTrainer::with_engine(p.clone(), 7, TrainerEngine::Reference).expect("valid params");
    let mut q =
        CoTmTrainer::with_engine(p.clone(), 7, TrainerEngine::Packed).expect("valid params");
    for _ in 0..CONVERGE_EPOCHS {
        r.epoch(d);
        q.epoch(d);
    }
    let case = Case {
        label: label.to_string(),
        reference_ms: time_epochs_ms(reps, || r.epoch(d)),
        packed_ms: time_epochs_ms(reps, || q.epoch(d)),
    };
    assert_eq!(r.export(), q.export(), "{label}: engines diverged");
    case
}

fn main() {
    println!("== packed-evaluation trainer vs per-literal reference ==");

    // Sanity first: a speedup over a *different* model is worthless.
    let sanity = data::xor_noise(150, 6, 0.05, 3);
    let sp = TmParams {
        features: 6,
        clauses: 8,
        classes: 2,
        ta_states: 32,
        threshold: 4,
        specificity: 3.0,
        max_weight: 7,
    };
    let a = train_multiclass_with(sp.clone(), &sanity, 3, 11, TrainerEngine::Reference)
        .expect("train");
    let b =
        train_multiclass_with(sp.clone(), &sanity, 3, 11, TrainerEngine::Packed).expect("train");
    assert_eq!(a, b, "same-seed bit-identity violated");
    let ca = train_cotm_with(sp.clone(), &sanity, 3, 13, TrainerEngine::Reference).expect("train");
    let cb = train_cotm_with(sp, &sanity, 3, 13, TrainerEngine::Packed).expect("train");
    assert_eq!(ca, cb, "same-seed bit-identity violated (cotm)");

    // (a) The paper's Iris configuration.
    let iris = data::iris().expect("iris");
    let (iris_train, _) = iris.split(0.8, 42);
    let iris_p = TmParams::iris_paper();

    // (b) The synthetic large regime: 256 features, 512 clauses.
    let (bf, bc, bk) = (256usize, 512usize, 4usize);
    let big = data::prototype_blobs(192, bf, bk, 0.1, 9);
    let big_p = TmParams {
        features: bf,
        clauses: bc,
        classes: bk,
        ta_states: 64,
        threshold: 16,
        specificity: 3.0,
        max_weight: 7,
    };

    let cases = vec![
        bench_multiclass("iris multiclass (16f, 12c, 3k)", &iris_p, &iris_train, 20),
        bench_cotm("iris cotm (16f, 12c, 3k)", &iris_p, &iris_train, 20),
        bench_multiclass(
            &format!("large multiclass ({bf}f, {bc}c/class, {bk}k)"),
            &big_p,
            &big,
            2,
        ),
        bench_cotm(&format!("large cotm ({bf}f, {bc}c shared, {bk}k)"), &big_p, &big, 2),
    ];

    let mut t = Table::new(vec![
        "trainer",
        "reference ms/epoch",
        "packed ms/epoch",
        "speedup",
    ]);
    let mut large_ok = true;
    for c in &cases {
        let speedup = c.reference_ms / c.packed_ms;
        if c.label.starts_with("large") && speedup < 4.0 {
            large_ok = false;
        }
        t.row(vec![
            c.label.clone(),
            format!("{:.2}", c.reference_ms),
            format!("{:.2}", c.packed_ms),
            format!("{speedup:.1}x"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "large-model target (>=4x epoch speedup over the reference trainer): {}",
        if large_ok { "PASS" } else { "FAIL" }
    );
}
