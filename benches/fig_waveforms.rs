//! Regenerates the waveform figures (**Figs. 6, 7, 8**) as VCD files
//! under `waves/`, and verifies the Fig. 6 property: for the Iris input
//! sequence, the DT-domain classifications grant the target classes
//! (2, 0, 1, 1).
//!
//! Run: `cargo bench --bench fig_waveforms` (then open in GTKWave)

use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::waveforms;
use tsetlin_td::arch::Architecture;
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use tsetlin_td::wta::WtaKind;

fn main() {
    std::fs::create_dir_all("waves").expect("mkdir waves");
    for line in waveforms::dump_all("waves").expect("dump") {
        println!("wrote {line}");
    }

    // Fig. 6 semantic check: the (2, 0, 1, 1) target sequence.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();
    let mut prop_mc = ProposedMulticlass::new(m, WtaKind::Tba).unwrap();
    let mut prop_co = ProposedCotm::new(cm, WtaKind::Tba).unwrap();

    let idx = [
        d.labels.iter().position(|&l| l == 2).unwrap(),
        d.labels.iter().position(|&l| l == 0).unwrap(),
        d.labels.iter().position(|&l| l == 1).unwrap(),
        d.labels.iter().rposition(|&l| l == 1).unwrap(),
    ];
    let targets = [2usize, 0, 1, 1];
    let mut mc_preds = Vec::new();
    let mut co_preds = Vec::new();
    for &i in &idx {
        mc_preds.push(prop_mc.infer(&d.features[i]).unwrap().predicted);
        co_preds.push(prop_co.infer(&d.features[i]).unwrap().predicted);
    }
    println!("fig6 target sequence {targets:?}");
    println!("  multiclass DT predictions: {mc_preds:?}");
    println!("  cotm       DT predictions: {co_preds:?}");
    assert_eq!(mc_preds, targets, "multiclass DT must predict (2,0,1,1)");
    assert_eq!(co_preds, targets, "CoTM DT must predict (2,0,1,1)");
    println!("fig6 sequence check: OK");
}
