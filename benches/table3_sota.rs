//! Regenerates **Table III** (state-of-the-art comparison). Literature
//! rows ([21], [4], [8], [11]) are quoted constants from the paper; the
//! two "Proposed" columns are measured from our simulator.
//!
//! Run: `cargo bench --bench table3_sota`

use tsetlin_td::arch::metrics::evaluate;
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use tsetlin_td::util::Table;
use tsetlin_td::wta::WtaKind;

fn main() {
    let d = data::iris().expect("iris");
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();
    let mut prop_mc = ProposedMulticlass::new(m, WtaKind::Tba).unwrap();
    let mut prop_co = ProposedCotm::new(cm, WtaKind::Tba).unwrap();
    let r_mc = evaluate(&mut prop_mc, &d.features, &d.labels).unwrap();
    let r_co = evaluate(&mut prop_co, &d.features, &d.labels).unwrap();

    let mut t = Table::new(vec![
        "Parameter", "[21]", "[4]", "[8]", "[11]", "Proposed TM", "Proposed CoTM",
    ]);
    t.row(vec!["Architecture", "Async QDI", "Async BD", "Sync", "Async QDI", "Async BD", "Async BD"]);
    t.row(vec!["Computing Domain", "Digital", "Digital", "Time", "Digital", "Time", "Hybrid"]);
    t.row(vec!["Technology (nm)", "65", "28", "65", "65", "65 (sim)", "65 (sim)"]);
    t.row(vec!["Voltage (V)", "1.2", "0.9", "1.2", "1.2", "1.0", "1.0"]);
    t.row(vec![
        "Energy Eff. (TOp/J)".to_string(),
        "1.87*".to_string(),
        "0.42*".to_string(),
        "116*".to_string(),
        "873*".to_string(),
        format!("{:.0}", r_mc.energy_eff_tops_per_j),
        format!("{:.0}", r_co.energy_eff_tops_per_j),
    ]);
    t.row(vec!["ML Algorithm", "CNN", "SNN", "BNN", "Multi-class TM", "Multi-class TM", "CoTM"]);
    println!("== Table III — SOTA comparison (* = reported in the paper) ==");
    println!("{}", t.render());

    // Shape claims: the proposed TM column tops the table; the CoTM
    // column sits between the TM-chip row [11] and the proposed TM
    // (paper: 3329 and 750.79 against 873).
    assert!(
        r_mc.energy_eff_tops_per_j > 873.0,
        "proposed TM must exceed the [11] TM chip ({:.0})",
        r_mc.energy_eff_tops_per_j
    );
    assert!(
        r_mc.energy_eff_tops_per_j > r_co.energy_eff_tops_per_j,
        "fully-time-domain TM beats hybrid CoTM on EE"
    );
    assert!(
        r_co.energy_eff_tops_per_j > 116.0,
        "hybrid CoTM beats the BNN time-domain chip [8]"
    );
    println!("shape assertions: OK");
}
