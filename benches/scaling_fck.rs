//! Scaling study: how the proposed architectures' advantage moves with
//! the model shape (the paper evaluates one Iris-sized point; this
//! sweeps class count K and clause count C on synthetic workloads to
//! show *where* the time-domain conversion pays: digital argmax
//! comparator trees and adder trees grow with K/C, while the race adds
//! only delay chains and ⌈log₂K⌉ arbiter layers).
//!
//! Run: `cargo bench --bench scaling_fck`

use tsetlin_td::arch::digital::{async_bd_cotm, sync_cotm};
use tsetlin_td::arch::metrics::evaluate;
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::Architecture;
use tsetlin_td::tm::{cotm_train::train_cotm, data, TmParams};
use tsetlin_td::util::Table;
use tsetlin_td::wta::WtaKind;

fn main() {
    let mut t = Table::new(vec![
        "K",
        "C",
        "sync TOp/J",
        "async TOp/J",
        "proposed TOp/J",
        "EE gain vs sync",
        "proposed TP gain vs sync",
    ]);
    let mut gains = Vec::new();
    for (k, c) in [(2usize, 8usize), (3, 12), (4, 16), (6, 24), (8, 32)] {
        let d = data::prototype_blobs(40 * k, 16, k, 0.05, 7);
        let params = TmParams {
            features: 16,
            clauses: c,
            classes: k,
            ..TmParams::iris_paper()
        };
        let model = train_cotm(params, &d, 40, 3).expect("train");
        let mut sync = sync_cotm(model.clone());
        let mut bd = async_bd_cotm(model.clone());
        let mut prop = ProposedCotm::new(model, WtaKind::Tba).expect("arch");
        let rs = evaluate(&mut sync, &d.features, &d.labels).unwrap();
        let rb = evaluate(&mut bd, &d.features, &d.labels).unwrap();
        let rp = evaluate(&mut prop, &d.features, &d.labels).unwrap();
        let ee_gain = rp.energy_eff_tops_per_j / rs.energy_eff_tops_per_j;
        let tp_gain = rp.throughput_gops / rs.throughput_gops;
        gains.push((k, ee_gain, tp_gain));
        t.row(vec![
            k.to_string(),
            c.to_string(),
            format!("{:.0}", rs.energy_eff_tops_per_j),
            format!("{:.0}", rb.energy_eff_tops_per_j),
            format!("{:.0}", rp.energy_eff_tops_per_j),
            format!("{ee_gain:.2}x"),
            format!("{tp_gain:.2}x"),
        ]);
    }
    println!("== CoTM scaling: shape (K, C) vs proposed advantage ==");
    println!("{}", t.render());

    // Shape claims: the proposed design's EE advantage holds at every
    // size, and the throughput gain does not collapse as K grows (the
    // WTA adds log-depth; the digital argmax adds linear comparator
    // width).
    for (k, ee, tp) in &gains {
        assert!(*ee > 1.3, "K={k}: EE gain {ee:.2} too small");
        assert!(*tp > 0.8, "K={k}: throughput ratio {tp:.2} collapsed");
    }
    println!("shape assertions: OK (advantage persists across shapes)");
}
