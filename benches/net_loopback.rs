//! Loopback bench for the networked serving tier: the TCP front door
//! ([`RemoteCoordinator`] → `ShardServer` processes-in-miniature on
//! 127.0.0.1) vs the in-process sharded coordinator on the same
//! models, same backends, same request stream — the per-request price
//! of the wire (framing + syscalls + one RTT) and how it amortises
//! over shard counts.
//!
//! Run: `cargo bench --bench net_loopback`

use std::time::Instant;

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::net::{RemoteCoordinator, ShardServer};
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest, ShardedCoordinator};
use tsetlin_td::tm::{
    cotm_train::train_cotm, data, train::train_multiclass, ModelCompiler, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

const REQUESTS: usize = 2_000;
const SHARD_COUNTS: [usize; 3] = [1, 2, 4];
const BACKENDS: [Backend; 2] = [Backend::AutoMulticlass, Backend::AutoCotm];

fn main() {
    let dataset = data::iris().unwrap();
    let (tr, _) = dataset.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 60, 3).unwrap();
    let base = ServeConfig { workers: 1, ..ServeConfig::default() };
    let compiler = ModelCompiler::new(base.compile);
    let cmc = compiler.compile_multiclass(&m).unwrap();
    let cco = compiler.compile_cotm(&cm).unwrap();

    let mut table = Table::new(vec![
        "shards".into(),
        "front door".into(),
        "req/s".into(),
        "p50 us".into(),
        "p99 us".into(),
    ]);

    for &n in &SHARD_COUNTS {
        // In-process baseline.
        let cfg = ServeConfig { shards: n, ..base.clone() };
        let local = ShardedCoordinator::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        let (rps, p50, p99) = drive(|x, b| {
            local
                .infer(InferRequest { features: x.to_vec(), backend: b })
                .map(|_| ())
                .unwrap()
        });
        local.shutdown();
        table.row(vec![
            n.to_string(),
            "in-process".into(),
            format!("{rps:.0}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
        ]);

        // Loopback TCP.
        let shards: Vec<ShardServer> = (0..n)
            .map(|_| {
                let srv =
                    CoordinatorServer::from_compiled_artifacts(&base, cmc.clone(), cco.clone())
                        .unwrap();
                ShardServer::bind(srv, "127.0.0.1:0").unwrap()
            })
            .collect();
        let addrs: Vec<String> = shards.iter().map(|s| s.local_addr().to_string()).collect();
        let router = RemoteCoordinator::connect(&addrs, 2, 0).unwrap();
        let (rps, p50, p99) = drive(|x, b| router.infer(x, b).map(|_| ()).unwrap());
        router.shutdown();
        for s in shards {
            s.shutdown();
        }
        table.row(vec![
            n.to_string(),
            "loopback tcp".into(),
            format!("{rps:.0}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "({REQUESTS} sequential auto-backend requests per cell; loopback overhead = \
         framing + 2 syscalls + RTT per request)"
    );
}

/// Drive the request stream; returns (req/s, p50 us, p99 us).
fn drive(mut f: impl FnMut(&[bool], Backend)) -> (f64, f64, f64) {
    let dataset = data::iris().unwrap();
    let mut rng = SplitMix64::new(1);
    // Warm-up: batchers, connection pools, page cache.
    for i in 0..100 {
        f(&dataset.features[i % dataset.len()], BACKENDS[i % BACKENDS.len()]);
    }
    let mut lat = Vec::with_capacity(REQUESTS);
    let t0 = Instant::now();
    for i in 0..REQUESTS {
        let x = &dataset.features[rng.index(dataset.len())];
        let b = BACKENDS[i % BACKENDS.len()];
        let r0 = Instant::now();
        f(x, b);
        lat.push(r0.elapsed().as_secs_f64() * 1e6);
    }
    let total = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pick = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
    (REQUESTS as f64 / total, pick(0.50), pick(0.99))
}
