//! Regenerates **Table IV** (performance summary): throughput (Eq. 3)
//! and energy efficiency (Eq. 4) for all six implementations on the
//! paper's Iris configuration (F=16, C=12, K=3), plus the paper's
//! reported values and the measured/paper ratio table that DESIGN.md's
//! shape criteria are judged against.
//!
//! Run: `cargo bench --bench table4_perf`

use tsetlin_td::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass,
};
use tsetlin_td::arch::metrics::{evaluate, render_table_iv, PerfRow};
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use tsetlin_td::util::Table;
use tsetlin_td::wta::WtaKind;

/// Paper Table IV rows: (implementation, GOp/s, TOp/J).
const PAPER: [(&str, f64, f64); 6] = [
    ("multiclass-sync", 380.0, 948.61),
    ("multiclass-async-bd", 510.0, 1381.65),
    ("multiclass-proposed", 402.0, 3290.00),
    ("cotm-sync", 230.0, 304.65),
    ("cotm-async-bd", 350.0, 397.60),
    ("cotm-proposed", 419.0, 750.79),
];

fn main() {
    let d = data::iris().expect("iris");
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2).expect("train tm");
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3).expect("train cotm");

    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap()),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm.clone(), WtaKind::Tba).unwrap()),
    ];
    let rows: Vec<PerfRow> = archs
        .iter_mut()
        .map(|a| evaluate(a.as_mut(), &d.features, &d.labels).expect("evaluate"))
        .collect();

    println!("== Table IV (measured, full Iris set, F=16 C=12 K=3) ==");
    println!("{}", render_table_iv(&rows));

    // Paper-vs-measured ratio table: the reproduction target is the
    // *shape* (who wins, by what factor), not absolute numbers — our
    // substrate is a calibrated simulator, not the authors' testbed.
    let mut t = Table::new(vec![
        "Implementation",
        "paper GOp/s",
        "meas GOp/s",
        "paper TOp/J",
        "meas TOp/J",
        "paper rel-TP",
        "meas rel-TP",
        "paper rel-EE",
        "meas rel-EE",
    ]);
    // Relative to each variant's sync baseline.
    let base = |name: &str| -> (usize, usize) {
        if name.starts_with("multiclass") {
            (0, 0)
        } else {
            (3, 3)
        }
    };
    for (i, (name, p_tp, p_ee)) in PAPER.iter().enumerate() {
        let (bi, _) = base(name);
        let r = &rows[i];
        t.row(vec![
            name.to_string(),
            format!("{p_tp:.0}"),
            format!("{:.0}", r.throughput_gops),
            format!("{p_ee:.0}"),
            format!("{:.0}", r.energy_eff_tops_per_j),
            format!("{:.2}x", p_tp / PAPER[bi].1),
            format!("{:.2}x", r.throughput_gops / rows[bi].throughput_gops),
            format!("{:.2}x", p_ee / PAPER[bi].2),
            format!("{:.2}x", r.energy_eff_tops_per_j / rows[bi].energy_eff_tops_per_j),
        ]);
    }
    println!("== Paper vs measured (relative to the sync baseline of each variant) ==");
    println!("{}", t.render());

    // Shape assertions (the claims the paper's Table IV makes).
    let tp = |i: usize| rows[i].throughput_gops;
    let ee = |i: usize| rows[i].energy_eff_tops_per_j;
    assert!(tp(1) > tp(0), "async-BD TM must out-run sync TM");
    assert!(tp(2) < tp(1), "proposed TM trades throughput vs async-BD");
    assert!(tp(2) > 0.7 * tp(0), "proposed TM roughly matches sync TM");
    assert!(ee(2) > 2.0 * ee(0), "proposed TM: large EE win vs sync");
    assert!(ee(2) > 1.5 * ee(1), "proposed TM: EE win vs async-BD");
    assert!(tp(4) > tp(3), "async-BD CoTM must out-run sync CoTM");
    assert!(tp(5) > tp(4), "proposed CoTM wins throughput vs async-BD");
    assert!(tp(5) > tp(3), "proposed CoTM wins throughput vs sync");
    assert!(ee(5) > 1.8 * ee(3), "proposed CoTM: EE win vs sync");
    assert!(ee(5) > 1.4 * ee(4), "proposed CoTM: EE win vs async-BD");
    assert!(ee(3) < ee(0), "CoTM baselines are less efficient than TM");
    println!("shape assertions: OK (all Table IV orderings hold)");
}
