//! Bit-parallel engine vs scalar reference — the perf-trajectory bench
//! for the production serving tier.
//!
//! Compares `tm::fast_infer` (packed words, skip lists, bit-sliced
//! batching, scoped-thread sharding) against the `tm::infer` scalar
//! reference on (a) the paper's Iris-sized model and (b) a synthetic
//! large model (256 features, 512 clauses/class — the regime word-level
//! packing is built for). Prints µs/sample and speedup; the large-model
//! batched path is the headline number.
//!
//! Run: `cargo bench --bench bitparallel_vs_ref`

use std::time::Instant;

use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums};
use tsetlin_td::tm::{
    data, train::train_multiclass, BatchEngine, BitParallelCotm, BitParallelMulticlass,
    ClauseMask, CoTmModel, MultiClassTmModel, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

/// Time `f` over `reps` repetitions of `samples` samples; µs/sample.
fn time_us_per_sample(samples: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    // One warm-up pass (page in, branch-train), then timed reps.
    f();
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / (reps * samples) as f64
}

fn random_mask(rng: &mut SplitMix64, literals: usize, density: f64) -> ClauseMask {
    ClauseMask { include: (0..literals).map(|_| rng.chance(density)).collect() }
}

fn synthetic_multiclass(f: usize, c: usize, k: usize, seed: u64) -> MultiClassTmModel {
    let p = TmParams {
        features: f,
        clauses: c,
        classes: k,
        ..TmParams::iris_paper()
    };
    let mut rng = SplitMix64::new(seed);
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = random_mask(&mut rng, 2 * f, 0.08);
        }
    }
    m
}

fn synthetic_cotm(f: usize, c: usize, k: usize, seed: u64) -> CoTmModel {
    let p = TmParams {
        features: f,
        clauses: c,
        classes: k,
        ..TmParams::iris_paper()
    };
    let mut rng = SplitMix64::new(seed);
    let mut m = CoTmModel::zeroed(p.clone());
    for clause in &mut m.clauses {
        *clause = random_mask(&mut rng, 2 * f, 0.08);
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = rng.next_below(2 * p.max_weight as u64 + 1) as i32 - p.max_weight;
        }
    }
    m
}

fn random_samples(f: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool()).collect()).collect()
}

struct Case {
    label: String,
    scalar_us: f64,
    single_us: f64,
    batched_us: f64,
    sharded_us: f64,
}

fn bench_multiclass(label: &str, m: &MultiClassTmModel, xs: &[Vec<bool>], reps: usize) -> Case {
    let e = BitParallelMulticlass::from_model(m).expect("valid model");
    // Sanity first: a speedup over wrong answers is worthless.
    for x in xs.iter().take(8) {
        assert_eq!(e.class_sums(x), multiclass_class_sums(m, x));
    }
    let n = xs.len();
    Case {
        label: label.to_string(),
        scalar_us: time_us_per_sample(n, reps, || {
            for x in xs {
                std::hint::black_box(multiclass_class_sums(m, x));
            }
        }),
        single_us: time_us_per_sample(n, reps, || {
            for x in xs {
                std::hint::black_box(e.class_sums(x));
            }
        }),
        batched_us: time_us_per_sample(n, reps, || {
            std::hint::black_box(e.infer_batch(xs));
        }),
        sharded_us: time_us_per_sample(n, reps, || {
            std::hint::black_box(e.infer_batch_sharded(xs, 4));
        }),
    }
}

fn bench_cotm(label: &str, m: &CoTmModel, xs: &[Vec<bool>], reps: usize) -> Case {
    let e = BitParallelCotm::from_model(m).expect("valid model");
    for x in xs.iter().take(8) {
        assert_eq!(e.class_sums(x), cotm_class_sums(m, x));
    }
    let n = xs.len();
    Case {
        label: label.to_string(),
        scalar_us: time_us_per_sample(n, reps, || {
            for x in xs {
                std::hint::black_box(cotm_class_sums(m, x));
            }
        }),
        single_us: time_us_per_sample(n, reps, || {
            for x in xs {
                std::hint::black_box(e.class_sums(x));
            }
        }),
        batched_us: time_us_per_sample(n, reps, || {
            std::hint::black_box(e.infer_batch(xs));
        }),
        sharded_us: time_us_per_sample(n, reps, || {
            std::hint::black_box(e.infer_batch_sharded(xs, 4));
        }),
    }
}

fn main() {
    println!("== bit-parallel engine vs scalar reference ==");

    // (a) Iris-sized trained model: the paper's configuration.
    let d = data::iris().expect("iris");
    let (tr, _) = d.split(0.8, 42);
    let iris_m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2).expect("train");

    // (b) Synthetic large models: >=256 features, >=512 clauses.
    let (bf, bc, bk) = (256usize, 512usize, 4usize);
    let big_m = synthetic_multiclass(bf, bc, bk, 7);
    let big_xs = random_samples(bf, 128, 9);
    let big_cm = synthetic_cotm(bf, bc, bk, 11);

    let cases = vec![
        bench_multiclass("iris multiclass (16f, 12c, 3k)", &iris_m, &d.features, 50),
        bench_multiclass(
            &format!("large multiclass ({bf}f, {bc}c/class, {bk}k)"),
            &big_m,
            &big_xs,
            3,
        ),
        bench_cotm(
            &format!("large cotm ({bf}f, {bc}c shared, {bk}k)"),
            &big_cm,
            &big_xs,
            10,
        ),
    ];

    let mut t = Table::new(vec![
        "model",
        "scalar us/sample",
        "bitpar single",
        "bitpar batched",
        "bitpar sharded(4)",
        "best speedup",
    ]);
    let mut large_ok = true;
    for c in &cases {
        let best = c.batched_us.min(c.single_us).min(c.sharded_us);
        let speedup = c.scalar_us / best;
        if c.label.starts_with("large") && speedup < 4.0 {
            large_ok = false;
        }
        t.row(vec![
            c.label.clone(),
            format!("{:.2}", c.scalar_us),
            format!("{:.2} ({:.1}x)", c.single_us, c.scalar_us / c.single_us),
            format!("{:.2} ({:.1}x)", c.batched_us, c.scalar_us / c.batched_us),
            format!("{:.2} ({:.1}x)", c.sharded_us, c.scalar_us / c.sharded_us),
            format!("{speedup:.1}x"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "large-model target (>=4x over scalar reference): {}",
        if large_ok { "PASS" } else { "FAIL" }
    );
}
