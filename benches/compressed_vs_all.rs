//! Compressed vs indexed vs bit-parallel vs scalar — the crossover
//! bench for the compressed-clause (ETHEREAL) serving tier.
//!
//! Cost models per sample: scalar walks all `C · 2F` literals; packed
//! spends ~`C · ceil(2F/64)` word ops regardless of sparsity; indexed
//! spends one counter op per (set literal, including clause) pair; the
//! compressed walk visits at most the include-list length per clause
//! and early-exits on the first unsatisfied literal — with hot
//! (high-frequency) literals reordered first so the expected walk is
//! short. This bench sweeps density on a large synthetic model and
//! prints all four engines µs per sample per point, plus where the
//! default *three-way* auto selection
//! ([`tsetlin_td::tm::compressed::select_engine`]) would route — the
//! empirical crossovers should bracket both default thresholds.
//!
//! Run: `cargo bench --bench compressed_vs_all`

use std::time::Instant;

use tsetlin_td::tm::compressed::{select_engine, PACKED_VS_COMPRESSED_DENSITY};
use tsetlin_td::tm::index::PACKED_VS_INDEXED_DENSITY;
use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums};
use tsetlin_td::tm::{
    BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    CompressedCotm, CompressedMulticlass, IndexedCotm, IndexedMulticlass,
    MultiClassTmModel, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

/// Densities spanning the indexed regime (below 0.05), the compressed
/// regime (0.05..0.2) and the packed regime (above 0.2).
const DENSITIES: [f64; 7] = [0.005, 0.01, 0.03, 0.06, 0.12, 0.25, 0.5];

/// Time `f` over `reps` repetitions of `samples` samples; µs/sample.
fn time_us_per_sample(samples: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm-up
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / (reps * samples) as f64
}

fn random_mask(rng: &mut SplitMix64, literals: usize, density: f64) -> ClauseMask {
    ClauseMask { include: (0..literals).map(|_| rng.chance(density)).collect() }
}

fn synthetic_multiclass(f: usize, c: usize, k: usize, density: f64, seed: u64) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = random_mask(&mut rng, 2 * f, density);
        }
    }
    m
}

fn synthetic_cotm(f: usize, c: usize, k: usize, density: f64, seed: u64) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = CoTmModel::zeroed(p.clone());
    for clause in &mut m.clauses {
        *clause = random_mask(&mut rng, 2 * f, density);
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = rng.next_below(2 * p.max_weight as u64 + 1) as i32 - p.max_weight;
        }
    }
    m
}

fn random_samples(f: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool()).collect()).collect()
}

fn main() {
    println!("== compressed vs indexed vs bit-parallel vs scalar (density sweep) ==");
    let (f, c, k) = (256usize, 512usize, 4usize);
    let xs = random_samples(f, 128, 9);
    let n = xs.len();

    let mut t = Table::new(vec![
        "density (target/actual)",
        "scalar us/sample",
        "bitpar batched",
        "indexed batched",
        "compressed batched",
        "compressed/bitpar",
        "auto picks",
    ]);
    for (di, &density) in DENSITIES.iter().enumerate() {
        let m = synthetic_multiclass(f, c, k, density, 7 + di as u64);
        let bp = BitParallelMulticlass::from_model(&m).expect("valid model");
        let ix = IndexedMulticlass::from_model(&m).expect("valid model");
        let cp = CompressedMulticlass::from_model(&m).expect("valid model");
        // Sanity first: a speedup over wrong answers is worthless.
        for x in xs.iter().take(4) {
            let want = multiclass_class_sums(&m, x);
            assert_eq!(bp.class_sums(x), want);
            assert_eq!(ix.class_sums(x), want);
            assert_eq!(cp.class_sums(x), want);
        }
        let scalar_us = time_us_per_sample(n, 3, || {
            for x in &xs {
                std::hint::black_box(multiclass_class_sums(&m, x));
            }
        });
        let bp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(bp.infer_batch(&xs));
        });
        let ix_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(ix.infer_batch(&xs));
        });
        let cp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(cp.infer_batch(&xs));
        });
        t.row(vec![
            format!("mc {density:.3}/{:.3}", cp.density()),
            format!("{scalar_us:.2}"),
            format!("{bp_us:.2} ({:.1}x)", scalar_us / bp_us),
            format!("{ix_us:.2} ({:.1}x)", scalar_us / ix_us),
            format!("{cp_us:.2} ({:.1}x)", scalar_us / cp_us),
            format!("{:.2}x", bp_us / cp_us),
            select_engine(
                cp.density(),
                PACKED_VS_INDEXED_DENSITY,
                PACKED_VS_COMPRESSED_DENSITY,
            )
            .name()
            .into(),
        ]);
    }
    for (di, &density) in DENSITIES.iter().enumerate() {
        let m = synthetic_cotm(f, c, k, density, 21 + di as u64);
        let bp = BitParallelCotm::from_model(&m).expect("valid model");
        let ix = IndexedCotm::from_model(&m).expect("valid model");
        let cp = CompressedCotm::from_model(&m).expect("valid model");
        for x in xs.iter().take(4) {
            let want = cotm_class_sums(&m, x);
            assert_eq!(bp.class_sums(x), want);
            assert_eq!(ix.class_sums(x), want);
            assert_eq!(cp.class_sums(x), want);
        }
        let scalar_us = time_us_per_sample(n, 3, || {
            for x in &xs {
                std::hint::black_box(cotm_class_sums(&m, x));
            }
        });
        let bp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(bp.infer_batch(&xs));
        });
        let ix_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(ix.infer_batch(&xs));
        });
        let cp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(cp.infer_batch(&xs));
        });
        t.row(vec![
            format!("co {density:.3}/{:.3}", cp.density()),
            format!("{scalar_us:.2}"),
            format!("{bp_us:.2} ({:.1}x)", scalar_us / bp_us),
            format!("{ix_us:.2} ({:.1}x)", scalar_us / ix_us),
            format!("{cp_us:.2} ({:.1}x)", scalar_us / cp_us),
            format!("{:.2}x", bp_us / cp_us),
            select_engine(
                cp.density(),
                PACKED_VS_INDEXED_DENSITY,
                PACKED_VS_COMPRESSED_DENSITY,
            )
            .name()
            .into(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "model: {f} features, {c} clauses/class, {k} classes; batch {n}; \
         auto thresholds {PACKED_VS_INDEXED_DENSITY} (indexed) / \
         {PACKED_VS_COMPRESSED_DENSITY} (compressed)"
    );
    println!(
        "expectation: compressed/bitpar > 1x in the sparse band and < 1x \
         well above the compressed threshold (the two empirical \
         crossovers should bracket the two defaults)."
    );
}
