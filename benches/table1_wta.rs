//! Regenerates **Table I** (theoretical analysis of WTA implementations)
//! plus measured latency/energy from the event simulator.
//!
//! Run: `cargo bench --bench table1_wta`

use tsetlin_td::sim::TechParams;
use tsetlin_td::util::Table;
use tsetlin_td::wta::{analysis, WtaKind};

fn main() {
    let tech = TechParams::tsmc65_digital();
    let mut t = Table::new(vec![
        "Config.",
        "m",
        "Arbitration Depth",
        "Cell Count",
        "Latency theory (ps)",
        "Latency measured (ps)",
        "Energy measured (fJ)",
    ]);
    for m in [2usize, 3, 4, 8, 16, 32, 64] {
        for kind in [WtaKind::Tba, WtaKind::Mesh] {
            let a = match kind {
                WtaKind::Tba => analysis::tba_analysis(m, &tech),
                WtaKind::Mesh => analysis::mesh_analysis(m, &tech),
            };
            t.row(vec![
                match kind {
                    WtaKind::Tba => "TBA".to_string(),
                    WtaKind::Mesh => "Mesh-Like".to_string(),
                },
                m.to_string(),
                a.arbitration_depth.to_string(),
                a.cell_count.to_string(),
                format!("{:.0}", a.latency_theory.as_ps_f64()),
                format!("{:.0}", analysis::measured_latency(kind, m, &tech).as_ps_f64()),
                format!("{:.1}", analysis::measured_energy_fj(kind, m, &tech)),
            ]);
        }
    }
    println!("== Table I — WTA implementations (theory vs event-sim) ==");
    println!("{}", t.render());

    // Table I's structural claims.
    let t8 = analysis::tba_analysis(8, &tech);
    let m8 = analysis::mesh_analysis(8, &tech);
    assert_eq!(t8.arbitration_depth, 3); // log2 m
    assert_eq!(t8.cell_count, 7); // m-1
    assert_eq!(m8.arbitration_depth, 7); // m-1
    assert_eq!(m8.cell_count, 28); // m(m-1)/2
    assert!(
        analysis::measured_energy_fj(WtaKind::Mesh, 16, &tech)
            > analysis::measured_energy_fj(WtaKind::Tba, 16, &tech),
        "mesh cell count must cost energy"
    );
    println!("shape assertions: OK");
}
