//! Compiled vs uncompiled serving — does the load-time compile pass
//! (`tm/compile.rs`) pay for itself at inference time?
//!
//! Two synthetic models per family: a fully-live one (the pass can
//! only help via plan selection / reordering, so parity is the bar)
//! and a 50 %-dead one, where half the clauses are dead on arrival
//! (alternating all-exclude and contradictory) — the shape real
//! trained TMs drift toward, and where dead-clause elimination must
//! show up directly in µs/sample. "Uncompiled" is `CompileMode::Off`
//! (dead clauses kept, model order), so both sides run the identical
//! engine code and the delta isolates the compile products.
//!
//! Prints µs/sample for every engine family in all three modes plus a
//! PASS/FAIL line: prune must be ≥ 1.3× off on the 50 %-dead model
//! for both packed engines (the dead half is pure overhead there).
//!
//! Run: `cargo bench --bench compile_effect`

use std::time::Instant;

use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums};
use tsetlin_td::tm::{
    BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    CompileMode, CompressedCotm, CompressedMulticlass, IndexedCotm,
    IndexedMulticlass, ModelCompiler, MultiClassTmModel, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

const SPEEDUP_BAR: f64 = 1.3;

/// Time `f` over `reps` repetitions of `samples` samples; µs/sample.
fn time_us_per_sample(samples: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm-up
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / (reps * samples) as f64
}

fn random_mask(rng: &mut SplitMix64, literals: usize, density: f64) -> ClauseMask {
    ClauseMask { include: (0..literals).map(|_| rng.chance(density)).collect() }
}

/// Dead mask in one of the two exact-prune shapes: all-exclude, or a
/// contradictory pair on top of random includes.
fn dead_mask(rng: &mut SplitMix64, literals: usize, density: f64, all_exclude: bool) -> ClauseMask {
    if all_exclude {
        return ClauseMask { include: vec![false; literals] };
    }
    let mut m = random_mask(rng, literals, density);
    let pair = 2 * (rng.next_below(literals as u64 / 2) as usize);
    m.include[pair] = true;
    m.include[pair + 1] = true;
    m
}

fn synthetic_multiclass(
    f: usize,
    c: usize,
    k: usize,
    density: f64,
    dead_fraction: f64,
    seed: u64,
) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for (j, clause) in class.iter_mut().enumerate() {
            *clause = if rng.chance(dead_fraction) {
                dead_mask(&mut rng, 2 * f, density, j % 2 == 0)
            } else {
                random_mask(&mut rng, 2 * f, density)
            };
        }
    }
    m
}

fn synthetic_cotm(
    f: usize,
    c: usize,
    k: usize,
    density: f64,
    dead_fraction: f64,
    seed: u64,
) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = CoTmModel::zeroed(p.clone());
    for (j, clause) in m.clauses.iter_mut().enumerate() {
        *clause = if rng.chance(dead_fraction) {
            dead_mask(&mut rng, 2 * f, density, j % 2 == 0)
        } else {
            random_mask(&mut rng, 2 * f, density)
        };
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = rng.next_below(2 * p.max_weight as u64 + 1) as i32 - p.max_weight;
        }
    }
    m
}

fn random_samples(f: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool()).collect()).collect()
}

fn compiler_for(mode: CompileMode, features: usize) -> ModelCompiler {
    let c = ModelCompiler::new(mode);
    if mode == CompileMode::Full {
        c.with_synthetic_calibration(features, 64, 11)
    } else {
        c
    }
}

const MODES: [CompileMode; 3] = [CompileMode::Off, CompileMode::Prune, CompileMode::Full];

fn main() {
    println!("== compile_effect: compiled vs uncompiled serving ==");
    let (f, c, k) = (256usize, 512usize, 4usize);
    let xs = random_samples(f, 128, 9);
    let n = xs.len();

    let mut t = Table::new(vec![
        "model / engine",
        "off us/sample",
        "prune",
        "full",
        "prune/off",
        "live/total",
    ]);
    // prune-vs-off speedups for the PASS/FAIL verdict, keyed by row label.
    let mut verdicts: Vec<(String, f64)> = Vec::new();

    for (label, dead_fraction) in [("live", 0.0), ("50%-dead", 0.5)] {
        let m = synthetic_multiclass(f, c, k, 0.08, dead_fraction, 7);
        let cm = synthetic_cotm(f, c, k, 0.08, dead_fraction, 21);

        // One compiled artifact pair per mode; all engines share it,
        // like the server.
        let mc = MODES.map(|mode| {
            compiler_for(mode, f).compile_multiclass(&m).expect("valid model")
        });
        let co = MODES.map(|mode| {
            compiler_for(mode, f).compile_cotm(&cm).expect("valid model")
        });
        let live = format!(
            "{}/{}",
            mc[0].stats.live_clauses, mc[0].stats.total_clauses
        );

        // Sanity first: a speedup over wrong answers is worthless —
        // every mode must serve the reference sums.
        for x in xs.iter().take(4) {
            let want_mc = multiclass_class_sums(&m, x);
            let want_co = cotm_class_sums(&cm, x);
            for (cmc, cco) in mc.iter().zip(co.iter()) {
                let bp = BitParallelMulticlass::from_compiled(cmc).expect("compiled");
                assert_eq!(bp.class_sums(x), want_mc, "{label} {:?}", cmc.mode);
                let bpc = BitParallelCotm::from_compiled(cco).expect("compiled");
                assert_eq!(bpc.class_sums(x), want_co, "{label} {:?}", cco.mode);
            }
        }

        let mut bench = |engine: &str, us: [f64; 3], live: &str| {
            let speedup = us[0] / us[1];
            t.row(vec![
                format!("{label} {engine}"),
                format!("{:.2}", us[0]),
                format!("{:.2} ({speedup:.2}x)", us[1]),
                format!("{:.2} ({:.2}x)", us[2], us[0] / us[2]),
                format!("{speedup:.2}x"),
                live.into(),
            ]);
            verdicts.push((format!("{label} {engine}"), speedup));
        };

        bench(
            "bitpar-mc",
            mc.each_ref().map(|cmc| {
                let e = BitParallelMulticlass::from_compiled(cmc).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
        bench(
            "bitpar-co",
            co.each_ref().map(|cco| {
                let e = BitParallelCotm::from_compiled(cco).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
        bench(
            "indexed-mc",
            mc.each_ref().map(|cmc| {
                let e = IndexedMulticlass::from_compiled(cmc).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
        bench(
            "indexed-co",
            co.each_ref().map(|cco| {
                let e = IndexedCotm::from_compiled(cco).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
        bench(
            "compressed-mc",
            mc.each_ref().map(|cmc| {
                let e = CompressedMulticlass::from_compiled(cmc).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
        bench(
            "compressed-co",
            co.each_ref().map(|cco| {
                let e = CompressedCotm::from_compiled(cco).expect("compiled");
                time_us_per_sample(n, 10, || {
                    std::hint::black_box(e.infer_batch(&xs));
                })
            }),
            &live,
        );
    }

    println!("{}", t.render());
    println!(
        "model: {f} features, {c} clauses(/class), {k} classes; batch {n}; \
         include density 0.08; full mode calibrated on 64 synthetic samples"
    );

    // The bar applies where pruning removes real work: the packed
    // engines scan every stored clause, so a 50%-dead model must serve
    // >= {SPEEDUP_BAR}x faster once pruned. (Indexed/compressed walks
    // already skip empty clauses, so their delta is reported but not
    // gated — all-exclude dead clauses cost them nothing to begin
    // with.)
    let gated: Vec<&(String, f64)> = verdicts
        .iter()
        .filter(|(name, _)| name.starts_with("50%-dead bitpar"))
        .collect();
    let ok = gated.iter().all(|(_, s)| *s >= SPEEDUP_BAR);
    for (name, s) in &gated {
        println!("  {name}: prune/off {s:.2}x (bar {SPEEDUP_BAR}x)");
    }
    println!(
        "verdict: {}",
        if ok { "PASS" } else { "FAIL" }
    );
    if !ok {
        std::process::exit(1);
    }
}
