//! Host-performance bench for the L3 hot paths (the §Perf targets in
//! EXPERIMENTS.md): event-simulator throughput (events/s), per-inference
//! wall time of every architecture, and coordinator serving throughput.
//!
//! Run: `cargo bench --bench sim_throughput`

use std::time::Instant;

use tsetlin_td::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass,
};
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};
use tsetlin_td::sim::energy::TechParams;
use tsetlin_td::sim::{Circuit, Logic, Time};
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use tsetlin_td::util::Table;
use tsetlin_td::wta::WtaKind;

/// Raw event-queue throughput: a long inverter chain pulsed repeatedly.
fn event_throughput() -> f64 {
    use tsetlin_td::gates::basic::{Gate, GateOp};
    let tech = TechParams::tsmc65_digital();
    let mut c = Circuit::new(tech.clone());
    let mut prev = c.net("n0");
    let input = prev;
    for i in 0..2_000 {
        let out = c.net(format!("n{}", i + 1));
        c.add(
            Box::new(Gate::new(format!("inv{i}"), GateOp::Inv, vec![prev], out, &tech)),
            vec![prev],
        );
        prev = out;
    }
    let t0 = Instant::now();
    for k in 0..200u64 {
        let v = if k % 2 == 0 { Logic::One } else { Logic::Zero };
        c.drive(input, v, Time::ps(1));
        c.run_to_quiescence().unwrap();
    }
    c.events_processed() as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== L3 host-performance profile ==");
    let evs = event_throughput();
    println!("event-sim throughput: {:.2} M events/s", evs / 1e6);

    let d = data::iris().expect("iris");
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();

    let mut t = Table::new(vec![
        "architecture",
        "host us/infer",
        "sim events/infer",
        "host inferences/s",
    ]);
    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap()),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm.clone(), WtaKind::Tba).unwrap()),
    ];
    for a in archs.iter_mut() {
        // warmup
        for x in d.features.iter().take(10) {
            a.infer(x).unwrap();
        }
        let t0 = Instant::now();
        let mut events = 0u64;
        let n = 300usize;
        for i in 0..n {
            events += a.infer(&d.features[i % d.len()]).unwrap().sim_events;
        }
        let dt = t0.elapsed().as_secs_f64();
        t.row(vec![
            a.name().to_string(),
            format!("{:.1}", dt * 1e6 / n as f64),
            format!("{:.0}", events as f64 / n as f64),
            format!("{:.0}", n as f64 / dt),
        ]);
    }
    println!("{}", t.render());

    // Coordinator serving throughput (simulated backends, no golden —
    // benches must run without artifacts too).
    let cfg = ServeConfig { workers: 4, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
    let n = 2_000usize;
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    let backends = [
        Backend::ProposedMulticlass,
        Backend::ProposedCotm,
        Backend::AsyncBdMulticlass,
        Backend::AsyncBdCotm,
    ];
    for i in 0..n {
        if let Ok(rx) = srv.submit(InferRequest {
            features: d.features[i % d.len()].clone(),
            backend: backends[i % backends.len()],
        }) {
            pending.push(rx);
        }
    }
    let served = pending
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
        .count();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "coordinator: {served}/{n} served in {:.2}s = {:.0} req/s (4 workers)",
        dt,
        served as f64 / dt
    );
    println!("{}", srv.stats().render());
    srv.shutdown();
}
