//! Indexed vs bit-parallel vs scalar — the density-sweep bench for the
//! event-driven inverted-index tier.
//!
//! The packed engine's cost per sample is ~`C · ceil(2F/64)` word ops
//! regardless of sparsity; the indexed engine's is one counter op per
//! (set literal, including clause) pair, so it scales with
//! included-literal density. This bench sweeps density on a large
//! synthetic model and prints scalar / packed / indexed µs per sample
//! per point, plus where the default auto-select threshold
//! ([`tsetlin_td::tm::index::PACKED_VS_INDEXED_DENSITY`]) would route —
//! the empirical crossover should bracket it.
//!
//! Run: `cargo bench --bench indexed_vs_bitpar`

use std::time::Instant;

use tsetlin_td::tm::index::{prefer_indexed, PACKED_VS_INDEXED_DENSITY};
use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums};
use tsetlin_td::tm::{
    BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    IndexedCotm, IndexedMulticlass, MultiClassTmModel, TmParams,
};
use tsetlin_td::util::{SplitMix64, Table};

const DENSITIES: [f64; 6] = [0.005, 0.01, 0.03, 0.06, 0.12, 0.25];

/// Time `f` over `reps` repetitions of `samples` samples; µs/sample.
fn time_us_per_sample(samples: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm-up
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e6 / (reps * samples) as f64
}

fn random_mask(rng: &mut SplitMix64, literals: usize, density: f64) -> ClauseMask {
    ClauseMask { include: (0..literals).map(|_| rng.chance(density)).collect() }
}

fn synthetic_multiclass(f: usize, c: usize, k: usize, density: f64, seed: u64) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = random_mask(&mut rng, 2 * f, density);
        }
    }
    m
}

fn synthetic_cotm(f: usize, c: usize, k: usize, density: f64, seed: u64) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut rng = SplitMix64::new(seed);
    let mut m = CoTmModel::zeroed(p.clone());
    for clause in &mut m.clauses {
        *clause = random_mask(&mut rng, 2 * f, density);
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = rng.next_below(2 * p.max_weight as u64 + 1) as i32 - p.max_weight;
        }
    }
    m
}

fn random_samples(f: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (0..f).map(|_| rng.next_bool()).collect()).collect()
}

fn main() {
    println!("== indexed vs bit-parallel vs scalar (density sweep) ==");
    let (f, c, k) = (256usize, 512usize, 4usize);
    let xs = random_samples(f, 128, 9);
    let n = xs.len();

    let mut t = Table::new(vec![
        "density (target/actual)",
        "scalar us/sample",
        "bitpar batched",
        "indexed batched",
        "indexed/bitpar",
        "auto picks",
    ]);
    for (di, &density) in DENSITIES.iter().enumerate() {
        let m = synthetic_multiclass(f, c, k, density, 7 + di as u64);
        let bp = BitParallelMulticlass::from_model(&m).expect("valid model");
        let ix = IndexedMulticlass::from_model(&m).expect("valid model");
        // Sanity first: a speedup over wrong answers is worthless.
        for x in xs.iter().take(4) {
            let want = multiclass_class_sums(&m, x);
            assert_eq!(bp.class_sums(x), want);
            assert_eq!(ix.class_sums(x), want);
        }
        let scalar_us = time_us_per_sample(n, 3, || {
            for x in &xs {
                std::hint::black_box(multiclass_class_sums(&m, x));
            }
        });
        let bp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(bp.infer_batch(&xs));
        });
        let ix_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(ix.infer_batch(&xs));
        });
        t.row(vec![
            format!("mc {density:.3}/{:.3}", ix.density()),
            format!("{scalar_us:.2}"),
            format!("{bp_us:.2} ({:.1}x)", scalar_us / bp_us),
            format!("{ix_us:.2} ({:.1}x)", scalar_us / ix_us),
            format!("{:.2}x", bp_us / ix_us),
            if prefer_indexed(ix.density(), PACKED_VS_INDEXED_DENSITY) {
                "indexed".into()
            } else {
                "bitpar".into()
            },
        ]);
    }
    for (di, &density) in DENSITIES.iter().enumerate() {
        let m = synthetic_cotm(f, c, k, density, 21 + di as u64);
        let bp = BitParallelCotm::from_model(&m).expect("valid model");
        let ix = IndexedCotm::from_model(&m).expect("valid model");
        for x in xs.iter().take(4) {
            let want = cotm_class_sums(&m, x);
            assert_eq!(bp.class_sums(x), want);
            assert_eq!(ix.class_sums(x), want);
        }
        let scalar_us = time_us_per_sample(n, 3, || {
            for x in &xs {
                std::hint::black_box(cotm_class_sums(&m, x));
            }
        });
        let bp_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(bp.infer_batch(&xs));
        });
        let ix_us = time_us_per_sample(n, 10, || {
            std::hint::black_box(ix.infer_batch(&xs));
        });
        t.row(vec![
            format!("co {density:.3}/{:.3}", ix.density()),
            format!("{scalar_us:.2}"),
            format!("{bp_us:.2} ({:.1}x)", scalar_us / bp_us),
            format!("{ix_us:.2} ({:.1}x)", scalar_us / ix_us),
            format!("{:.2}x", bp_us / ix_us),
            if prefer_indexed(ix.density(), PACKED_VS_INDEXED_DENSITY) {
                "indexed".into()
            } else {
                "bitpar".into()
            },
        ]);
    }
    println!("{}", t.render());
    println!(
        "model: {f} features, {c} clauses/class, {k} classes; batch {n}; \
         auto threshold {PACKED_VS_INDEXED_DENSITY}"
    );
    println!(
        "expectation: indexed/bitpar > 1x below the threshold and < 1x well \
         above it (the crossover should bracket the default)."
    );
}
