//! **End-to-end driver** (DESIGN.md §8): the full three-layer stack on
//! the paper's real workload.
//!
//! 1. Train a multi-class TM and a CoTM on the real Iris dataset
//!    (F=16 booleanised features, C=12 clauses, K=3 classes — §III-A).
//! 2. Functional verification: all six event-driven hardware
//!    architectures agree with the software reference, and the
//!    AOT-compiled L2 JAX/Pallas golden model (via PJRT) agrees
//!    bit-exactly with the rust reference — the paper's "all logically
//!    equivalent implementations achieve identical accuracy".
//! 3. Reproduce Table IV on the trained models.
//! 4. Serve a batched request stream through the coordinator (golden
//!    functional path + simulated paths) and report latency/throughput.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example iris_e2e`

use std::time::Instant;

use tsetlin_td::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass,
};
use tsetlin_td::arch::metrics::{evaluate, render_table_iv};
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};
use tsetlin_td::tm::{cotm_train::train_cotm, data, infer, train::train_multiclass, TmParams};
use tsetlin_td::util::SplitMix64;
use tsetlin_td::wta::WtaKind;

fn main() -> tsetlin_td::Result<()> {
    println!("=== 1. Train on real Iris (150 samples, 16 bool features, 3 classes) ===");
    let d = data::iris()?;
    let (tr, te) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2)?;
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3)?;
    println!(
        "multiclass TM: train {:.1}% / test {:.1}%",
        100.0 * infer::multiclass_accuracy(&m, &tr.features, &tr.labels),
        100.0 * infer::multiclass_accuracy(&m, &te.features, &te.labels)
    );
    println!(
        "CoTM:          train {:.1}% / test {:.1}%",
        100.0 * infer::cotm_accuracy(&cm, &tr.features, &tr.labels),
        100.0 * infer::cotm_accuracy(&cm, &te.features, &te.labels)
    );

    println!("\n=== 2. Functional verification across all implementations ===");
    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), WtaKind::Tba)?),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm.clone(), WtaKind::Tba)?),
    ];
    for a in archs.iter_mut() {
        let mut agree = 0usize;
        let mut acc = 0usize;
        for (x, &y) in d.features.iter().zip(&d.labels) {
            let r = a.infer(x)?;
            let exact = infer::predict_argmax(&r.class_sums);
            // A WTA tie may grant a different *maximiser* — equally correct.
            if r.predicted == exact || r.class_sums[r.predicted] == r.class_sums[exact] {
                agree += 1;
            }
            if r.predicted == y {
                acc += 1;
            }
        }
        println!(
            "{:24} argmax agreement {:5.1}%   accuracy {:5.1}%",
            a.name(),
            100.0 * agree as f64 / d.len() as f64,
            100.0 * acc as f64 / d.len() as f64
        );
    }

    let with_golden = std::path::Path::new("artifacts/manifest.json").exists();
    if with_golden {
        println!("\n=== 2b. Golden model (AOT JAX/Pallas via PJRT) vs rust reference ===");
        let svc = tsetlin_td::runtime::GoldenService::spawn(
            "artifacts".into(),
            tsetlin_td::runtime::golden::GoldenModels {
                multiclass_include: m.include_f32(),
                cotm_include: cm.include_f32(),
                cotm_weights: cm.weights_f32(),
            },
        )?;
        let rows: Vec<Vec<f32>> = d
            .features
            .iter()
            .map(|r| r.iter().map(|&b| b as u8 as f32).collect())
            .collect();
        let mut mism = 0usize;
        for (family, reference) in [("multiclass_tm", true), ("cotm", false)] {
            let out = svc.infer_batch(family, rows.clone())?;
            for (i, (sums, _)) in out.iter().enumerate() {
                let want = if reference {
                    infer::multiclass_class_sums(&m, &d.features[i])
                } else {
                    infer::cotm_class_sums(&cm, &d.features[i])
                };
                let got: Vec<i32> = sums.iter().map(|&x| x as i32).collect();
                if got != want {
                    mism += 1;
                }
            }
            println!("{family}: {} samples, {mism} mismatches", out.len());
        }
        assert_eq!(mism, 0, "golden model must match bit-exactly");
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the golden-model check)");
    }

    println!("\n=== 3. Table IV on the trained models ===");
    let mut rows = Vec::new();
    for a in archs.iter_mut() {
        rows.push(evaluate(a.as_mut(), &d.features, &d.labels)?);
    }
    println!("{}", render_table_iv(&rows));

    println!("=== 4. Serve a batched request stream through the coordinator ===");
    let cfg = ServeConfig { workers: 4, max_batch: 16, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m, cm, with_golden)?;
    let n = 1000usize;
    let mut rng = SplitMix64::new(5);
    let backends: Vec<Backend> = Backend::ALL
        .iter()
        .copied()
        .filter(|b| with_golden || !b.is_golden())
        .collect();
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let b = backends[rng.index(backends.len())];
        match srv.submit(InferRequest {
            features: d.features[i % d.len()].clone(),
            backend: b,
        }) {
            Ok(rx) => pending.push(rx),
            Err(_) => {} // backpressure: counted in stats
        }
    }
    let ok = pending
        .into_iter()
        .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
        .count();
    let dt = t0.elapsed();
    println!(
        "served {ok}/{n} requests in {:.1} ms -> {:.0} req/s",
        dt.as_secs_f64() * 1e3,
        ok as f64 / dt.as_secs_f64()
    );
    println!("{}", srv.stats().render());
    srv.shutdown();

    println!("\niris_e2e OK");
    Ok(())
}
