//! Serving-coordinator demo: a mixed request stream across every
//! backend, with dynamic batching on the golden (AOT/PJRT) path,
//! per-backend routing, worker-pool hardware simulation, and
//! backpressure.
//!
//! Run: `make artifacts && cargo run --release --example serve_demo`
//! (works without artifacts too: `--no-golden` falls back automatically)

use std::time::Instant;

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};
use tsetlin_td::util::SplitMix64;

fn main() -> tsetlin_td::Result<()> {
    let d = data::iris()?;
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 60, 2)?;
    let cm = train_cotm(TmParams::iris_paper(), &tr, 150, 3)?;

    let with_golden = std::path::Path::new("artifacts/manifest.json").exists();
    let cfg = ServeConfig {
        workers: 4,
        max_batch: 16,
        batch_timeout_us: 300,
        queue_depth: 512,
        ..ServeConfig::default()
    };
    println!("coordinator config: {cfg:?}");
    let srv = CoordinatorServer::new(&cfg, m, cm, with_golden)?;

    // Phase 1: golden-path burst — watch the batcher coalesce.
    if with_golden {
        println!("\n-- phase 1: 256-request golden burst (dynamic batching) --");
        let t0 = Instant::now();
        let pending: Vec<_> = (0..256)
            .filter_map(|i| {
                srv.submit(InferRequest {
                    features: d.features[i % d.len()].clone(),
                    backend: if i % 2 == 0 {
                        Backend::GoldenMulticlass
                    } else {
                        Backend::GoldenCotm
                    },
                })
                .ok()
            })
            .collect();
        let ok = pending
            .into_iter()
            .filter(|rx| rx.recv().map(|r| r.is_ok()).unwrap_or(false))
            .count();
        println!(
            "golden burst: {ok}/256 in {:.1} ms; {}",
            t0.elapsed().as_secs_f64() * 1e3,
            srv.stats().render()
        );
    }

    // Phase 2: mixed hardware-model traffic with per-request energy.
    // Native batched backends (bitpar-*/indexed-*/auto-*) carry no
    // hardware energy model, so they would only print misleading
    // 0 fJ/inf rows here.
    println!("\n-- phase 2: mixed hardware-simulation traffic --");
    let mut rng = SplitMix64::new(3);
    let hw: Vec<Backend> = Backend::ALL
        .iter()
        .copied()
        .filter(|b| !b.is_golden() && !b.is_native_batched() && !b.is_auto())
        .collect();
    let t0 = Instant::now();
    let mut per_backend: std::collections::BTreeMap<&str, (usize, f64)> = Default::default();
    let mut pending = Vec::new();
    for i in 0..600 {
        let b = *rng.pick_slice(&hw);
        if let Ok(rx) = srv.submit(InferRequest {
            features: d.features[i % d.len()].clone(),
            backend: b,
        }) {
            pending.push(rx);
        }
    }
    for rx in pending {
        if let Ok(Ok(r)) = rx.recv() {
            let e = per_backend.entry(r.backend.name()).or_default();
            e.0 += 1;
            e.1 += r.hw_energy_fj.unwrap_or(0.0);
        }
    }
    println!("mixed phase took {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    for (name, (count, energy)) in &per_backend {
        println!(
            "  {name:24} {count:4} reqs, mean hardware energy {:.0} fJ/inf",
            energy / *count as f64
        );
    }

    println!("\nfinal stats: {}", srv.stats().render());
    srv.shutdown();
    Ok(())
}

trait PickSlice {
    fn pick_slice<'a, T>(&mut self, xs: &'a [T]) -> &'a T;
}
impl PickSlice for SplitMix64 {
    fn pick_slice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}
