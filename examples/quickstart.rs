//! Quickstart: train a tiny Tsetlin machine on noisy XOR and run it
//! through the proposed event-driven time-domain architecture.
//!
//! Run: `cargo run --release --example quickstart`

use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, InferRequest, ShardedCoordinator};
use tsetlin_td::tm::{
    compressed, cotm_train::train_cotm, data, index, infer,
    train::{train_multiclass, train_multiclass_with},
    BatchEngine, BitParallelMulticlass, CompressedMulticlass, IndexedMulticlass,
    SimdLevel, TmParams, TrainerEngine, WordLanes,
};
use tsetlin_td::wta::WtaKind;

fn main() -> tsetlin_td::Result<()> {
    // 1. A dataset: XOR of the first two bits, 5% label noise.
    let train = data::xor_noise(400, 4, 0.05, 11);
    let test = data::xor_noise(200, 4, 0.0, 99);

    // 2. Train a multi-class TM (2 classes, 10 clauses).
    let params = TmParams {
        features: 4,
        clauses: 10,
        classes: 2,
        ta_states: 64,
        threshold: 5,
        specificity: 3.0,
        max_weight: 7,
    };
    //    Training runs through the packed-evaluation engine by default
    //    (incrementally-maintained packed include masks, word-wide
    //    clause evaluation); the per-literal reference engine produces
    //    a bit-identical model for the same seed — the trainer-parity
    //    contract `tmtd selfcheck` also enforces.
    let model = train_multiclass(params.clone(), &train, 30, 1)?;
    let reference =
        train_multiclass_with(params.clone(), &train, 30, 1, TrainerEngine::Reference)?;
    assert_eq!(model, reference, "packed trainer must match reference bit-for-bit");
    let acc = infer::multiclass_accuracy(&model, &test.features, &test.labels);
    println!("software accuracy on clean XOR: {:.1}% (packed == reference trainer)", 100.0 * acc);

    // 2b. The production serving path: compile the model into the
    //     bit-parallel engine (packed-word clause evaluation, batched
    //     64 samples per word). Bit-exact with the scalar reference.
    let fast = BitParallelMulticlass::from_model(&model)?;
    let batch = fast.infer_batch(&test.features);
    let fast_correct = batch
        .iter()
        .zip(&test.labels)
        .filter(|((_, pred), &y)| *pred == y)
        .count();
    println!(
        "bit-parallel engine: {}/{} batched predictions correct (identical to reference)",
        fast_correct,
        test.features.len()
    );
    assert_eq!(
        fast.class_sums(&test.features[0]),
        infer::multiclass_class_sums(&model, &test.features[0]),
        "bit-parallel path must be bit-exact"
    );

    // 2b''. SIMD dispatch: the engine evaluates in multi-word lanes
    //       (portable 4x-unrolled, AVX2, AVX-512 behind runtime
    //       detection). The lane width is a speed decision only —
    //       every available level produces identical batches.
    for level in SimdLevel::available() {
        let lev = fast.clone().with_lanes(WordLanes::new(level)?);
        assert_eq!(
            lev.infer_batch(&test.features),
            batch,
            "simd level {} must match the portable reference",
            level.name()
        );
    }
    println!(
        "simd lanes: auto resolves to {} here; all of [{}] are bit-identical",
        SimdLevel::detect_best().name(),
        SimdLevel::available()
            .iter()
            .map(|l| l.name())
            .collect::<Vec<_>>()
            .join(", ")
    );

    // 2b'. The event-driven alternative: the inverted-index engine
    //      visits only the clauses a sample's set literals touch
    //      (literal->clause postings + unsatisfied-literal counters).
    //      Identical sums, different cost model — it wins when the
    //      model is sparse. `auto-*` backends pick per model by
    //      included-literal density.
    let indexed = IndexedMulticlass::from_model(&model)?;
    for x in test.features.iter().take(16) {
        assert_eq!(
            indexed.class_sums(x),
            fast.class_sums(x),
            "indexed and packed engines are interchangeable"
        );
    }

    // 2b'''. The compressed-clause tier (ETHEREAL-style): each clause
    //        stored as its sorted include-literal list with hot
    //        literals walked first; evaluation early-exits on the
    //        first unsatisfied literal. Third member of the same
    //        bit-exact family — `auto-*` picks indexed vs compressed
    //        vs packed per model by included-literal density.
    let compressed = CompressedMulticlass::from_model(&model)?;
    for x in test.features.iter().take(16) {
        assert_eq!(
            compressed.class_sums(x),
            fast.class_sums(x),
            "compressed and packed engines are interchangeable"
        );
    }
    println!(
        "event-driven tiers: density {:.3} -> auto-select would use {}",
        indexed.density(),
        compressed::select_engine(
            indexed.density(),
            index::PACKED_VS_INDEXED_DENSITY,
            compressed::PACKED_VS_COMPRESSED_DENSITY,
        )
        .name()
    );

    // 2c. Scale-out serving: front two coordinator shards with a
    //     deterministic consistent-hash ring. The same feature vector
    //     always routes to the same shard, batched replies come back
    //     relay-free on the caller's channel, and every shard is
    //     bit-exact with the scalar reference.
    let cotm = train_cotm(params, &train, 30, 2)?;
    let cfg = ServeConfig {
        shards: 2,
        workers: 1,
        max_batch: 8,
        ..ServeConfig::default()
    };
    let srv = ShardedCoordinator::new(&cfg, model.clone(), cotm, false)?;
    for (i, x) in test.features.iter().take(8).enumerate() {
        // Alternate the packed, indexed, compressed and auto-selected
        // native backends: all four must produce identical sums.
        let backend = [
            Backend::BitParallelMulticlass,
            Backend::IndexedMulticlass,
            Backend::CompressedMulticlass,
            Backend::AutoMulticlass,
        ][i % 4];
        let r = srv.infer(InferRequest { features: x.clone(), backend })?;
        assert_eq!(
            r.class_sums,
            infer::multiclass_class_sums(&model, x),
            "sharded front door must be bit-exact via {backend:?}"
        );
    }
    let agg = srv.stats();
    println!(
        "sharded front door: {} requests over {} shards (sample 0 -> shard {}), all bit-exact",
        agg.completed,
        srv.num_shards(),
        srv.shard_for_features(&test.features[0])
    );
    srv.shutdown();

    // 3. Instantiate the proposed digital-time-domain architecture:
    //    clause evaluation stays digital; class sums become Hamming-race
    //    delays; a tree of Mutexes (WTA) picks the first arrival.
    let mut hw = ProposedMulticlass::new(model, WtaKind::Tba)?;

    // 4. Infer a few samples and look at the hardware-cost annotations.
    for (i, x) in test.features.iter().take(5).enumerate() {
        let r = hw.infer(x)?;
        println!(
            "sample {i}: x={:?} -> class {} (sums {:?}), latency {}, energy {:.1} fJ, {} sim events",
            x.iter().map(|&b| b as u8).collect::<Vec<_>>(),
            r.predicted,
            r.class_sums,
            r.latency,
            r.energy_fj,
            r.sim_events
        );
    }

    // 5. Architecture-level summary.
    println!(
        "cycle time {} -> f_infer {:.0} MHz; {} gate-equivalents, {:.1} nW leakage",
        hw.cycle_time(),
        1e3 / hw.cycle_time().as_ns_f64() / 1e3 * 1e3, // MHz
        hw.gate_equivalents(),
        hw.leakage_power_nw()
    );
    Ok(())
}
