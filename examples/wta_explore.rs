//! WTA design-space exploration (Table I, extended): arbitrate races of
//! growing class counts on both topologies, watch latency, energy, cell
//! count, and metastability-dwell behaviour under shrinking margins.
//!
//! Run: `cargo run --release --example wta_explore`

use tsetlin_td::sim::energy::TechParams;
use tsetlin_td::sim::{Circuit, Logic, NetId, Time};
use tsetlin_td::util::Table;
use tsetlin_td::wta::{self, analysis, WtaKind};

fn main() -> tsetlin_td::Result<()> {
    let tech = TechParams::tsmc65_digital();

    println!("== Table I (theory) ==");
    let mut t = Table::new(vec!["Config.", "Arbitration Depth", "Cell Count", "Arbitration Latency"]);
    t.row(vec![
        "TBA".to_string(),
        "log2 m".to_string(),
        "m-1".to_string(),
        "log2 m (d_Mutex + d_OR + d_C)".to_string(),
    ]);
    t.row(vec![
        "Mesh-Like".to_string(),
        "m-1".to_string(),
        "m(m-1)/2".to_string(),
        "(m-1) d_Mutex".to_string(),
    ]);
    println!("{}", t.render());

    println!("== Measured sweep ==");
    let mut t = Table::new(vec![
        "m", "kind", "cells", "latency (ps)", "energy (fJ)",
    ]);
    for m in [2usize, 3, 4, 6, 8, 12, 16, 24, 32] {
        for kind in [WtaKind::Tba, WtaKind::Mesh] {
            let cells = match kind {
                WtaKind::Tba => m - 1,
                WtaKind::Mesh => m * (m - 1) / 2,
            };
            t.row(vec![
                m.to_string(),
                kind.name().to_string(),
                cells.to_string(),
                format!("{:.0}", analysis::measured_latency(kind, m, &tech).as_ps_f64()),
                format!("{:.1}", analysis::measured_energy_fj(kind, m, &tech)),
            ]);
        }
    }
    println!("{}", t.render());

    // Metastability gallery: two near-simultaneous arrivals, decreasing gap.
    println!("== Metastability dwell vs arrival gap (single Mutex pair) ==");
    let mut t = Table::new(vec!["gap (ps)", "grant latency (ps)", "dwell over nominal (ps)"]);
    for gap in [500u64, 100, 48, 24, 12, 6, 3, 1, 0] {
        let mut c = Circuit::new(tech.clone());
        let r1 = c.net_init("r1", Logic::Zero);
        let r2 = c.net_init("r2", Logic::Zero);
        let arb = wta::build(&mut c, WtaKind::Tba, "mx", &[r1, r2]);
        c.init_components();
        c.run_to_quiescence()?;
        let t0 = Time::ps(100);
        c.drive(r1, Logic::One, t0);
        c.drive(r2, Logic::One, t0 + Time::ps(gap));
        let grants: Vec<NetId> = arb.grants.clone();
        c.run_while(Time::ns(100), |cc| {
            grants.iter().any(|g| cc.value(*g) == Logic::One)
        })?;
        let latency = c.now().since(t0);
        let nominal = Time::ps(40); // d_nand + d_inv at 1.2 V
        t.row(vec![
            gap.to_string(),
            format!("{:.0}", latency.as_ps_f64()),
            format!("{:.0}", latency.since(nominal).as_ps_f64()),
        ]);
    }
    println!("{}", t.render());
    println!("dwell follows t = tau_m * ln(window/gap): the analytic metastability model.");
    Ok(())
}
