//! Dump the paper's waveform figures (Figs. 6–8) as VCD files.
//!
//! Run: `cargo run --release --example waveform_dump [out_dir]`
//! View: `gtkwave waves/fig6a_multiclass_dt.vcd`

use tsetlin_td::arch::waveforms;

fn main() -> tsetlin_td::Result<()> {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "waves".into());
    std::fs::create_dir_all(&out_dir)?;
    for line in waveforms::dump_all(&out_dir)? {
        println!("wrote {line}");
    }
    println!("\nopen with GTKWave, e.g.: gtkwave {out_dir}/fig6b_cotm_dt.vcd");
    Ok(())
}
