//! Same-seed conformance suite for the packed-evaluation trainers.
//!
//! The PR's headline invariant: because packed clause evaluation is
//! exact and consumes no randomness, a trainer running on
//! [`TrainerEngine::Packed`] must produce a model **bit-identical** to
//! the same-seed trainer on [`TrainerEngine::Reference`] — not
//! statistically similar, identical, down to every TA-derived include
//! bit and every CoTM weight. Feature widths deliberately straddle the
//! packed-word boundaries (F=32 is exactly one 64-literal word, 33
//! spills into a tail word; 63/64/65 are the two-word boundary), the
//! acceptance sweep of the issue.
//!
//! Alongside bit-identity, the trainer invariants are fuzzed at the
//! trainer level (every TA in `1..=2N` after arbitrary epochs; the
//! incremental include mask always equals a from-scratch recompute),
//! and a trained-Iris model is pushed end-to-end through the serving
//! engines (scalar reference, bit-parallel, inverted-index) to show
//! accuracy parity is preserved all the way to the tiers users hit.
//!
//! PR 10 extends the suite to the async clause-parallel tier, whose
//! bar is deliberately split: structural invariants must hold under
//! real concurrency (TA bounds, mask == recompute after the partition
//! join, vote conservation — checked inside every epoch), the
//! `--threads 1` degenerate case must equal the deterministic
//! schedule bit-for-bit, indexed feedback must equal packed feedback
//! whenever the schedule is deterministic, and accuracy (not bits)
//! must land within epsilon of the reference tier over seeded runs.

use tsetlin_td::testutil::prop;
use tsetlin_td::tm::cotm_train::{train_cotm_with, CoTmTrainer};
use tsetlin_td::tm::infer::{cotm_accuracy, multiclass_accuracy, predict_argmax};
use tsetlin_td::tm::train::{train_multiclass_with, MultiClassTrainer};
use tsetlin_td::tm::{
    data, train_multiclass_async, AsyncCoTmTrainer, AsyncMultiClassTrainer, BatchEngine,
    BitParallelCotm, BitParallelMulticlass, Dataset, IndexedCotm, IndexedMulticlass, TmParams,
    TrainerEngine,
};

/// The acceptance sweep: literal-space word boundaries.
const BOUNDARY_WIDTHS: [usize; 6] = [31, 32, 33, 63, 64, 65];

fn params(f: usize, clauses: usize, classes: usize) -> TmParams {
    TmParams {
        features: f,
        clauses,
        classes,
        ta_states: 32,
        threshold: 4,
        specificity: 3.0,
        max_weight: 5,
    }
}

fn blobs(f: usize, classes: usize, seed: u64) -> Dataset {
    data::prototype_blobs(60, f, classes, 0.1, seed)
}

#[test]
fn multiclass_packed_trainer_bit_identical_across_boundary_widths() {
    for &f in &BOUNDARY_WIDTHS {
        let d = blobs(f, 3, f as u64);
        let p = params(f, 8, 3);
        let a = train_multiclass_with(p.clone(), &d, 4, 99, TrainerEngine::Reference).unwrap();
        let b = train_multiclass_with(p, &d, 4, 99, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b, "multiclass diverged at f={f}");
        // Non-vacuous: training actually moved some TAs past the
        // include boundary.
        assert!(
            b.clauses.iter().flatten().any(|cl| cl.included_count() > 0),
            "f={f}: trained model has no included literals — sweep is vacuous"
        );
    }
}

#[test]
fn cotm_packed_trainer_bit_identical_across_boundary_widths() {
    for &f in &BOUNDARY_WIDTHS {
        let d = blobs(f, 3, f as u64 + 1);
        let p = params(f, 7, 3); // odd pool size is legal for CoTM
        let a = train_cotm_with(p.clone(), &d, 4, 77, TrainerEngine::Reference).unwrap();
        let b = train_cotm_with(p, &d, 4, 77, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b, "cotm diverged at f={f}");
        assert!(
            b.clauses.iter().any(|cl| cl.included_count() > 0),
            "f={f}: trained CoTM has no included literals — sweep is vacuous"
        );
    }
}

#[test]
fn random_shapes_same_seed_equality() {
    // The invariant is structural, not a property of any particular
    // configuration: random widths, clause counts, class counts,
    // epochs and seeds.
    prop("packed == reference on random shapes", 25, |g| {
        let f = g.usize(1..48);
        let classes = g.usize(2..5);
        let clauses = 2 * g.usize(1..5);
        let seed = g.u64(0..u64::MAX);
        let epochs = g.usize(1..4);
        let d = data::prototype_blobs(24, f, classes, 0.2, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses,
            classes,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 4,
        };
        let a = train_multiclass_with(p.clone(), &d, epochs, seed, TrainerEngine::Reference)
            .unwrap();
        let b = train_multiclass_with(p.clone(), &d, epochs, seed, TrainerEngine::Packed)
            .unwrap();
        assert_eq!(a, b, "multiclass f={f} k={classes} c={clauses}");
        let ca = train_cotm_with(p.clone(), &d, epochs, seed, TrainerEngine::Reference).unwrap();
        let cb = train_cotm_with(p, &d, epochs, seed, TrainerEngine::Packed).unwrap();
        assert_eq!(ca, cb, "cotm f={f} k={classes} c={clauses}");
    });
}

#[test]
fn trainer_invariants_hold_after_arbitrary_epochs() {
    // Every TA stays in 1..=2N and every incremental include mask
    // equals the from-scratch recompute, after each epoch (the update
    // batch granularity), for both trainer kinds on the packed engine.
    prop("trainer invariants", 12, |g| {
        let f = g.usize(1..40);
        let classes = g.usize(2..4);
        let n = [8u32, 16, 32][g.usize(0..3)];
        let d = data::prototype_blobs(30, f, classes, 0.15, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses: 6,
            classes,
            ta_states: n,
            threshold: 3,
            specificity: 2.5,
            max_weight: 3,
        };
        let seed = g.u64(0..u64::MAX);
        let mut mc = MultiClassTrainer::with_engine(p.clone(), seed, TrainerEngine::Packed)
            .unwrap();
        let mut co = CoTmTrainer::with_engine(p, seed, TrainerEngine::Packed).unwrap();
        let epochs = g.usize(1..6);
        for _ in 0..epochs {
            mc.epoch(&d);
            mc.check_invariants().expect("multiclass invariants");
            co.epoch(&d);
            co.check_invariants().expect("cotm invariants");
        }
    });
}

#[test]
fn trained_iris_parity_end_to_end_through_serving_engines() {
    // Models from both engines are identical, and the identical model
    // serves identically through every native tier: scalar reference,
    // bit-parallel, inverted-index — so training-engine choice can
    // never shift served accuracy.
    let d = data::iris().unwrap();
    let (train, test) = d.split(0.8, 42);
    let p = TmParams::iris_paper();

    let m_ref = train_multiclass_with(p.clone(), &train, 25, 2, TrainerEngine::Reference).unwrap();
    let m_pk = train_multiclass_with(p.clone(), &train, 25, 2, TrainerEngine::Packed).unwrap();
    assert_eq!(m_ref, m_pk, "iris multiclass models diverged");

    let cm_ref = train_cotm_with(p.clone(), &train, 60, 3, TrainerEngine::Reference).unwrap();
    let cm_pk = train_cotm_with(p, &train, 60, 3, TrainerEngine::Packed).unwrap();
    assert_eq!(cm_ref, cm_pk, "iris cotm models diverged");

    let want_mc = multiclass_accuracy(&m_pk, &test.features, &test.labels);
    let want_co = cotm_accuracy(&cm_pk, &test.features, &test.labels);

    let bp_mc = BitParallelMulticlass::from_model(&m_pk).unwrap();
    let ix_mc = IndexedMulticlass::from_model(&m_pk).unwrap();
    let bp_co = BitParallelCotm::from_model(&cm_pk).unwrap();
    let ix_co = IndexedCotm::from_model(&cm_pk).unwrap();

    let acc_through = |sums: &dyn Fn(&[bool]) -> Vec<i32>| -> f64 {
        let correct = test
            .features
            .iter()
            .zip(&test.labels)
            .filter(|(x, &y)| predict_argmax(&sums(x)) == y)
            .count();
        correct as f64 / test.features.len() as f64
    };
    assert_eq!(acc_through(&|x| bp_mc.class_sums(x)), want_mc, "bitpar multiclass");
    assert_eq!(acc_through(&|x| ix_mc.class_sums(x)), want_mc, "indexed multiclass");
    assert_eq!(acc_through(&|x| bp_co.class_sums(x)), want_co, "bitpar cotm");
    assert_eq!(acc_through(&|x| ix_co.class_sums(x)), want_co, "indexed cotm");
}

// ---------------------------------------------------------------------------
// The async clause-parallel tier (PR 10).

#[test]
fn async_trainer_invariants_hold_under_real_concurrency() {
    // Threaded (racing) epochs across random shapes, thread counts and
    // both feedback engines: every TA in 1..=2N, every incremental
    // include mask equal to the recompute after the join, every
    // per-worker index coherent. The vote conservation law (no lost
    // updates on partition boundaries) is asserted inside epoch()
    // itself — a violated law fails the Result, not just the check.
    prop("async invariants under threads", 10, |g| {
        let f = g.usize(1..40);
        let classes = g.usize(2..4);
        let clauses = 2 * g.usize(1..5);
        let threads = g.usize(1..9);
        let indexed = g.bool();
        let seed = g.u64(0..u64::MAX);
        let d = data::prototype_blobs(24, f, classes, 0.2, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses,
            classes,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 4,
        };
        let mut mc = AsyncMultiClassTrainer::new(p.clone(), seed, threads, indexed).unwrap();
        let mut co = AsyncCoTmTrainer::new(p, seed, threads, indexed).unwrap();
        for _ in 0..g.usize(1..4) {
            mc.epoch(&d.features, &d.labels).expect("multiclass epoch");
            mc.check_invariants().expect("multiclass async invariants");
            co.epoch(&d.features, &d.labels).expect("cotm epoch");
            co.check_invariants().expect("cotm async invariants");
        }
    });
}

#[test]
fn async_threads_one_degenerate_case_equals_deterministic_schedule() {
    // `--threads 1` regression bar: with a single worker the threaded
    // schedule IS the deterministic round-robin schedule (one worker,
    // sample-major order, same RNG streams), so the two paths must
    // produce bit-identical models — the async tier at one thread has
    // reference semantics, not merely reference-like statistics.
    for &f in &[5usize, 33, 64] {
        let d = blobs(f, 3, f as u64 + 7);
        let p = params(f, 6, 3);
        for &indexed in &[false, true] {
            let mut a = AsyncMultiClassTrainer::new(p.clone(), 11, 1, indexed).unwrap();
            let mut b = AsyncMultiClassTrainer::new(p.clone(), 11, 1, indexed).unwrap();
            let ma = a.train(&d.features, &d.labels, 3).unwrap();
            let mb = b.train_deterministic(&d.features, &d.labels, 3).unwrap();
            assert_eq!(ma, mb, "multiclass f={f} indexed={indexed}");
            let mut ca = AsyncCoTmTrainer::new(p.clone(), 12, 1, indexed).unwrap();
            let mut cb = AsyncCoTmTrainer::new(p.clone(), 12, 1, indexed).unwrap();
            let wa = ca.train(&d.features, &d.labels, 3).unwrap();
            let wb = cb.train_deterministic(&d.features, &d.labels, 3).unwrap();
            assert_eq!(wa, wb, "cotm f={f} indexed={indexed}");
        }
    }
}

#[test]
fn async_indexed_feedback_equals_packed_under_deterministic_schedule() {
    // Evaluation through the inverted index is exact and consumes no
    // randomness, so with the schedule pinned the indexed engine must
    // be bit-identical to the packed engine at any thread count.
    prop("async indexed == packed", 12, |g| {
        let f = g.usize(1..48);
        let classes = g.usize(1..4);
        let clauses = 2 * g.usize(1..5);
        let threads = g.usize(1..6);
        let seed = g.u64(0..u64::MAX);
        let epochs = g.usize(1..3);
        let d = data::prototype_blobs(20, f, classes, 0.2, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses,
            classes,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 4,
        };
        let mut a = AsyncMultiClassTrainer::new(p.clone(), seed, threads, false).unwrap();
        let mut b = AsyncMultiClassTrainer::new(p.clone(), seed, threads, true).unwrap();
        assert_eq!(
            a.train_deterministic(&d.features, &d.labels, epochs).unwrap(),
            b.train_deterministic(&d.features, &d.labels, epochs).unwrap(),
            "multiclass f={f} threads={threads}"
        );
        let mut ca = AsyncCoTmTrainer::new(p.clone(), seed, threads, false).unwrap();
        let mut cb = AsyncCoTmTrainer::new(p, seed, threads, true).unwrap();
        assert_eq!(
            ca.train_deterministic(&d.features, &d.labels, epochs).unwrap(),
            cb.train_deterministic(&d.features, &d.labels, epochs).unwrap(),
            "cotm f={f} threads={threads}"
        );
    });
}

#[test]
fn async_accuracy_within_epsilon_of_reference_trainer() {
    // The async tier's statistical bar (same epsilon as `tmtd
    // selfcheck` and the Python mirror's pytest suite): racing workers
    // against stale class sums must not cost real accuracy. Bits are
    // deliberately NOT compared — nondeterminism is the design.
    const EPS: f64 = 0.15;
    let p = TmParams {
        features: 20,
        clauses: 10,
        classes: 3,
        ta_states: 32,
        threshold: 8,
        specificity: 3.0,
        max_weight: 5,
    };
    for seed in [1u64, 2, 3] {
        let d = data::prototype_blobs(90, 20, 3, 0.05, seed);
        let m_ref =
            train_multiclass_with(p.clone(), &d, 10, seed, TrainerEngine::Packed).unwrap();
        let m_async = train_multiclass_async(p.clone(), &d, 10, seed, 4, false).unwrap();
        let ra = multiclass_accuracy(&m_ref, &d.features, &d.labels);
        let aa = multiclass_accuracy(&m_async, &d.features, &d.labels);
        assert!(ra > 0.6, "seed {seed}: reference tier failed to learn (acc {ra})");
        assert!(
            (ra - aa).abs() <= EPS,
            "seed {seed}: async accuracy {aa} drifted from reference {ra} (eps {EPS})"
        );
    }
}
