//! Same-seed conformance suite for the packed-evaluation trainers.
//!
//! The PR's headline invariant: because packed clause evaluation is
//! exact and consumes no randomness, a trainer running on
//! [`TrainerEngine::Packed`] must produce a model **bit-identical** to
//! the same-seed trainer on [`TrainerEngine::Reference`] — not
//! statistically similar, identical, down to every TA-derived include
//! bit and every CoTM weight. Feature widths deliberately straddle the
//! packed-word boundaries (F=32 is exactly one 64-literal word, 33
//! spills into a tail word; 63/64/65 are the two-word boundary), the
//! acceptance sweep of the issue.
//!
//! Alongside bit-identity, the trainer invariants are fuzzed at the
//! trainer level (every TA in `1..=2N` after arbitrary epochs; the
//! incremental include mask always equals a from-scratch recompute),
//! and a trained-Iris model is pushed end-to-end through the serving
//! engines (scalar reference, bit-parallel, inverted-index) to show
//! accuracy parity is preserved all the way to the tiers users hit.

use tsetlin_td::testutil::prop;
use tsetlin_td::tm::cotm_train::{train_cotm_with, CoTmTrainer};
use tsetlin_td::tm::infer::{cotm_accuracy, multiclass_accuracy, predict_argmax};
use tsetlin_td::tm::train::{train_multiclass_with, MultiClassTrainer};
use tsetlin_td::tm::{
    data, BatchEngine, BitParallelCotm, BitParallelMulticlass, Dataset, IndexedCotm,
    IndexedMulticlass, TmParams, TrainerEngine,
};

/// The acceptance sweep: literal-space word boundaries.
const BOUNDARY_WIDTHS: [usize; 6] = [31, 32, 33, 63, 64, 65];

fn params(f: usize, clauses: usize, classes: usize) -> TmParams {
    TmParams {
        features: f,
        clauses,
        classes,
        ta_states: 32,
        threshold: 4,
        specificity: 3.0,
        max_weight: 5,
    }
}

fn blobs(f: usize, classes: usize, seed: u64) -> Dataset {
    data::prototype_blobs(60, f, classes, 0.1, seed)
}

#[test]
fn multiclass_packed_trainer_bit_identical_across_boundary_widths() {
    for &f in &BOUNDARY_WIDTHS {
        let d = blobs(f, 3, f as u64);
        let p = params(f, 8, 3);
        let a = train_multiclass_with(p.clone(), &d, 4, 99, TrainerEngine::Reference).unwrap();
        let b = train_multiclass_with(p, &d, 4, 99, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b, "multiclass diverged at f={f}");
        // Non-vacuous: training actually moved some TAs past the
        // include boundary.
        assert!(
            b.clauses.iter().flatten().any(|cl| cl.included_count() > 0),
            "f={f}: trained model has no included literals — sweep is vacuous"
        );
    }
}

#[test]
fn cotm_packed_trainer_bit_identical_across_boundary_widths() {
    for &f in &BOUNDARY_WIDTHS {
        let d = blobs(f, 3, f as u64 + 1);
        let p = params(f, 7, 3); // odd pool size is legal for CoTM
        let a = train_cotm_with(p.clone(), &d, 4, 77, TrainerEngine::Reference).unwrap();
        let b = train_cotm_with(p, &d, 4, 77, TrainerEngine::Packed).unwrap();
        assert_eq!(a, b, "cotm diverged at f={f}");
        assert!(
            b.clauses.iter().any(|cl| cl.included_count() > 0),
            "f={f}: trained CoTM has no included literals — sweep is vacuous"
        );
    }
}

#[test]
fn random_shapes_same_seed_equality() {
    // The invariant is structural, not a property of any particular
    // configuration: random widths, clause counts, class counts,
    // epochs and seeds.
    prop("packed == reference on random shapes", 25, |g| {
        let f = g.usize(1..48);
        let classes = g.usize(2..5);
        let clauses = 2 * g.usize(1..5);
        let seed = g.u64(0..u64::MAX);
        let epochs = g.usize(1..4);
        let d = data::prototype_blobs(24, f, classes, 0.2, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses,
            classes,
            ta_states: 16,
            threshold: 3,
            specificity: 3.0,
            max_weight: 4,
        };
        let a = train_multiclass_with(p.clone(), &d, epochs, seed, TrainerEngine::Reference)
            .unwrap();
        let b = train_multiclass_with(p.clone(), &d, epochs, seed, TrainerEngine::Packed)
            .unwrap();
        assert_eq!(a, b, "multiclass f={f} k={classes} c={clauses}");
        let ca = train_cotm_with(p.clone(), &d, epochs, seed, TrainerEngine::Reference).unwrap();
        let cb = train_cotm_with(p, &d, epochs, seed, TrainerEngine::Packed).unwrap();
        assert_eq!(ca, cb, "cotm f={f} k={classes} c={clauses}");
    });
}

#[test]
fn trainer_invariants_hold_after_arbitrary_epochs() {
    // Every TA stays in 1..=2N and every incremental include mask
    // equals the from-scratch recompute, after each epoch (the update
    // batch granularity), for both trainer kinds on the packed engine.
    prop("trainer invariants", 12, |g| {
        let f = g.usize(1..40);
        let classes = g.usize(2..4);
        let n = [8u32, 16, 32][g.usize(0..3)];
        let d = data::prototype_blobs(30, f, classes, 0.15, g.u64(0..u64::MAX));
        let p = TmParams {
            features: f,
            clauses: 6,
            classes,
            ta_states: n,
            threshold: 3,
            specificity: 2.5,
            max_weight: 3,
        };
        let seed = g.u64(0..u64::MAX);
        let mut mc = MultiClassTrainer::with_engine(p.clone(), seed, TrainerEngine::Packed)
            .unwrap();
        let mut co = CoTmTrainer::with_engine(p, seed, TrainerEngine::Packed).unwrap();
        let epochs = g.usize(1..6);
        for _ in 0..epochs {
            mc.epoch(&d);
            mc.check_invariants().expect("multiclass invariants");
            co.epoch(&d);
            co.check_invariants().expect("cotm invariants");
        }
    });
}

#[test]
fn trained_iris_parity_end_to_end_through_serving_engines() {
    // Models from both engines are identical, and the identical model
    // serves identically through every native tier: scalar reference,
    // bit-parallel, inverted-index — so training-engine choice can
    // never shift served accuracy.
    let d = data::iris().unwrap();
    let (train, test) = d.split(0.8, 42);
    let p = TmParams::iris_paper();

    let m_ref = train_multiclass_with(p.clone(), &train, 25, 2, TrainerEngine::Reference).unwrap();
    let m_pk = train_multiclass_with(p.clone(), &train, 25, 2, TrainerEngine::Packed).unwrap();
    assert_eq!(m_ref, m_pk, "iris multiclass models diverged");

    let cm_ref = train_cotm_with(p.clone(), &train, 60, 3, TrainerEngine::Reference).unwrap();
    let cm_pk = train_cotm_with(p, &train, 60, 3, TrainerEngine::Packed).unwrap();
    assert_eq!(cm_ref, cm_pk, "iris cotm models diverged");

    let want_mc = multiclass_accuracy(&m_pk, &test.features, &test.labels);
    let want_co = cotm_accuracy(&cm_pk, &test.features, &test.labels);

    let bp_mc = BitParallelMulticlass::from_model(&m_pk).unwrap();
    let ix_mc = IndexedMulticlass::from_model(&m_pk).unwrap();
    let bp_co = BitParallelCotm::from_model(&cm_pk).unwrap();
    let ix_co = IndexedCotm::from_model(&cm_pk).unwrap();

    let acc_through = |sums: &dyn Fn(&[bool]) -> Vec<i32>| -> f64 {
        let correct = test
            .features
            .iter()
            .zip(&test.labels)
            .filter(|(x, &y)| predict_argmax(&sums(x)) == y)
            .count();
        correct as f64 / test.features.len() as f64
    };
    assert_eq!(acc_through(&|x| bp_mc.class_sums(x)), want_mc, "bitpar multiclass");
    assert_eq!(acc_through(&|x| ix_mc.class_sums(x)), want_mc, "indexed multiclass");
    assert_eq!(acc_through(&|x| bp_co.class_sums(x)), want_co, "bitpar cotm");
    assert_eq!(acc_through(&|x| ix_co.class_sums(x)), want_co, "indexed cotm");
}
