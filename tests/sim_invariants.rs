//! Property-based invariants of the simulation substrate: mutual
//! exclusion under arbitrary schedules, WTA one-hot + first-arrival,
//! LOD monotonicity, Hamming-race exactness, click-pipeline token
//! conservation, and energy-accounting sanity.

use tsetlin_td::gates::mutex::Mutex;
use tsetlin_td::sim::energy::TechParams;
use tsetlin_td::sim::{Circuit, EnergyKind, Logic, NetId, Time};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::timedomain::lod;
use tsetlin_td::wta::{self, WtaKind};

#[test]
fn mutex_mutual_exclusion_under_random_schedules() {
    prop("mutex exclusion", 60, |g| {
        let tech = TechParams::tsmc65_digital();
        let mut c = Circuit::new(tech);
        let r1 = c.net_init("r1", Logic::Zero);
        let r2 = c.net_init("r2", Logic::Zero);
        let (g1, g2) = Mutex::build(&mut c, "mx", r1, r2);
        c.init_components();
        c.run_to_quiescence().unwrap();
        // Random 4-phase request schedule on both sides.
        let mut t = Time::ps(g.u64(1..50));
        for _ in 0..g.usize(1..6) {
            let side = if g.bool() { r1 } else { r2 };
            c.drive(side, Logic::One, t);
            t = t + Time::ps(g.u64(1..120));
            // Run and check exclusion after every event burst.
            c.run_to_quiescence().unwrap();
            assert!(
                !(c.value(g1) == Logic::One && c.value(g2) == Logic::One),
                "both grants high"
            );
            if g.bool() {
                c.drive(side, Logic::Zero, Time::ps(g.u64(1..80)));
                c.run_to_quiescence().unwrap();
                assert!(
                    !(c.value(g1) == Logic::One && c.value(g2) == Logic::One)
                );
            }
        }
    });
}

#[test]
fn wta_grants_one_hot_and_first_arrival_with_margin() {
    prop("wta one-hot/first-arrival", 30, |g| {
        let kind = if g.bool() { WtaKind::Tba } else { WtaKind::Mesh };
        let m = g.usize(2..9);
        let winner = g.usize(0..m);
        // Winner leads by >= 150 ps (beyond any dwell spread), others
        // randomly spread behind.
        let mut delays: Vec<u64> = (0..m)
            .map(|i| {
                if i == winner {
                    100
                } else {
                    250 + g.u64(0..500)
                }
            })
            .collect();
        delays[winner] = 100;
        let tech = TechParams::tsmc65_digital();
        let mut c = Circuit::new(tech);
        let races: Vec<NetId> = (0..m)
            .map(|i| c.net_init(format!("race{i}"), Logic::Zero))
            .collect();
        let arb = wta::build(&mut c, kind, "wta", &races);
        c.init_components();
        c.run_to_quiescence().unwrap();
        for (i, &r) in races.iter().enumerate() {
            c.drive(r, Logic::One, Time::ps(delays[i]));
        }
        c.run_to_quiescence().unwrap();
        let granted: Vec<usize> = arb
            .grants
            .iter()
            .enumerate()
            .filter(|(_, g)| c.value(**g) == Logic::One)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(granted, vec![winner], "kind={kind:?} delays={delays:?}");
    });
}

#[test]
fn lod_delay_monotone_for_random_fine_bits() {
    prop("lod monotone", 20, |g| {
        let e = g.u64(1..8) as u32;
        let mut prev = 0u64;
        for v in 0..2048u64 {
            let d = lod::lod_delay_units(v, e);
            assert!(d >= prev, "e={e} v={v}");
            prev = d;
        }
    });
}

#[test]
fn click_pipeline_conserves_tokens() {
    use tsetlin_td::async_ctrl::click::ClickElement;
    use tsetlin_td::gates::basic::{Gate, GateOp};
    prop("click token conservation", 15, |g| {
        let tech = TechParams::tsmc65_digital();
        let stages = g.usize(1..5);
        let tokens = g.usize(1..8);
        let mut c = Circuit::new(tech.clone());
        let rst = c.net_init("rst", Logic::Zero);
        let req0 = c.net_init("req0", Logic::Zero);
        let req_out: Vec<NetId> = (0..stages).map(|i| c.net(format!("req{}", i + 1))).collect();
        let ack_out: Vec<NetId> = (0..stages).map(|i| c.net(format!("acko{i}"))).collect();
        let fires: Vec<NetId> = (0..stages).map(|i| c.net(format!("fire{i}"))).collect();
        let sink_ack = c.net("sink_ack");
        c.add(
            Box::new(Gate::new(
                "sink",
                GateOp::Buf,
                vec![req_out[stages - 1]],
                sink_ack,
                &tech,
            )),
            vec![req_out[stages - 1]],
        );
        for i in 0..stages {
            let req_in = if i == 0 { req0 } else { req_out[i - 1] };
            let ack_in = if i == stages - 1 { sink_ack } else { ack_out[i + 1] };
            c.add(
                Box::new(ClickElement::new(
                    format!("click{i}"),
                    req_in,
                    ack_in,
                    rst,
                    req_out[i],
                    ack_out[i],
                    fires[i],
                    &tech,
                )),
                vec![req_in, ack_in, rst],
            );
        }
        c.init_components();
        c.run_to_quiescence().unwrap();
        let fire_base: Vec<u64> = fires.iter().map(|f| c.transitions(*f)).collect();
        for tok in 0..tokens {
            let v = if tok % 2 == 0 { Logic::One } else { Logic::Zero };
            c.drive(req0, v, Time::ps(g.u64(1..200)));
            c.run_to_quiescence().unwrap();
        }
        // Every stage fired exactly `tokens` times (2 transitions per
        // fire pulse) — tokens are neither lost nor duplicated.
        for (i, f) in fires.iter().enumerate() {
            let pulses = (c.transitions(*f) - fire_base[i]) / 2;
            assert_eq!(pulses as usize, tokens, "stage {i}");
        }
    });
}

#[test]
fn energy_never_negative_and_monotone_over_time() {
    prop("energy monotone", 10, |g| {
        use tsetlin_td::gates::basic::{Gate, GateOp};
        let tech = TechParams::tsmc65_digital();
        let mut c = Circuit::new(tech.clone());
        let a = c.net_init("a", Logic::Zero);
        let b = c.net_init("b", Logic::Zero);
        let o = c.net("o");
        c.add(
            Box::new(Gate::new("g", GateOp::Xor, vec![a, b], o, &tech)),
            vec![a, b],
        );
        let mut last = 0.0f64;
        for _ in 0..g.usize(2..20) {
            let net = if g.bool() { a } else { b };
            let v = if g.bool() { Logic::One } else { Logic::Zero };
            c.drive(net, v, Time::ps(g.u64(1..100)));
            c.run_to_quiescence().unwrap();
            let e = c.energy.total_dynamic_fj();
            assert!(e >= last, "energy decreased: {e} < {last}");
            assert!(e >= 0.0);
            last = e;
        }
    });
}

#[test]
fn leakage_scales_linearly_with_simulated_time() {
    let tech = TechParams::tsmc65_digital();
    let mut led = tsetlin_td::sim::EnergyLedger::default();
    led.gate_equivalents = 500.0;
    let e1 = led.leakage_fj(&tech, Time::ns(100));
    let e2 = led.leakage_fj(&tech, Time::ns(300));
    assert!((e2 / e1 - 3.0).abs() < 1e-9);
    let _ = EnergyKind::Leakage; // category exists for reports
}
