//! End-to-end tests of the networked serving tier (loopback TCP):
//! differential conformance against the in-process sharded
//! coordinator, counter conservation across the process boundary,
//! degraded-but-correct service while a shard is down (and recovery
//! when it returns), adversarial bytes on the wire, and graceful
//! drain.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::net::frame::{HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION};
use tsetlin_td::coordinator::net::msg::Msg;
use tsetlin_td::coordinator::net::{RemoteCoordinator, ShardServer};
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest, ShardedCoordinator};
use tsetlin_td::tm::compile::{CompiledCotm, CompiledMulticlass};
use tsetlin_td::tm::{
    cotm_train::train_cotm, data, train::train_multiclass, ModelCompiler, TmParams,
};

/// The backends a pinned-artifact shard serves (no golden artifacts,
/// no hardware pool in the shard process).
const NATIVE: [Backend; 8] = [
    Backend::BitParallelMulticlass,
    Backend::BitParallelCotm,
    Backend::IndexedMulticlass,
    Backend::IndexedCotm,
    Backend::CompressedMulticlass,
    Backend::CompressedCotm,
    Backend::AutoMulticlass,
    Backend::AutoCotm,
];

struct Fixture {
    cfg: ServeConfig,
    cmc: CompiledMulticlass,
    cco: CompiledCotm,
    m: tsetlin_td::tm::MultiClassTmModel,
    cm: tsetlin_td::tm::CoTmModel,
    dataset: data::Dataset,
}

fn fixture() -> Fixture {
    let dataset = data::iris().unwrap();
    let (tr, _) = dataset.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 60, 3).unwrap();
    let cfg = ServeConfig { workers: 1, net_heartbeat_ms: 50, ..ServeConfig::default() };
    let compiler = ModelCompiler::new(cfg.compile);
    let cmc = compiler.compile_multiclass(&m).unwrap();
    let cco = compiler.compile_cotm(&cm).unwrap();
    Fixture { cfg, cmc, cco, m, cm, dataset }
}

impl Fixture {
    fn spawn_shard(&self) -> ShardServer {
        let server = CoordinatorServer::from_compiled_artifacts(
            &self.cfg,
            self.cmc.clone(),
            self.cco.clone(),
        )
        .unwrap();
        ShardServer::bind(server, "127.0.0.1:0").unwrap()
    }

    fn spawn_cluster(&self, n: usize) -> (Vec<ShardServer>, Vec<String>) {
        let shards: Vec<ShardServer> = (0..n).map(|_| self.spawn_shard()).collect();
        let addrs = shards.iter().map(|s| s.local_addr().to_string()).collect();
        (shards, addrs)
    }
}

fn wait_for<F: Fn() -> bool>(what: &str, deadline: Duration, f: F) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn tcp_front_door_is_bit_identical_to_in_process_coordinator() {
    let fx = fixture();
    let (shards, addrs) = fx.spawn_cluster(3);
    let router = RemoteCoordinator::connect(&addrs, 2, 0).unwrap();

    // The in-process reference: same config, same shard count, models
    // compiled by the same pass.
    let cfg = ServeConfig { shards: 3, ..fx.cfg.clone() };
    let local = ShardedCoordinator::new(&cfg, fx.m.clone(), fx.cm.clone(), false).unwrap();

    for (i, x) in fx.dataset.features.iter().enumerate() {
        // Identical ring, identical routing decision.
        assert_eq!(
            router.shard_for_features(x),
            local.shard_for_features(x),
            "sample {i} routed differently over TCP"
        );
        let backend = NATIVE[i % NATIVE.len()];
        let remote = router.infer(x, backend).unwrap();
        let reference = local.infer(InferRequest { features: x.clone(), backend }).unwrap();
        assert_eq!(remote.class_sums, reference.class_sums, "sample {i} sums diverge");
        assert_eq!(remote.predicted, reference.predicted, "sample {i} argmax diverges");
        // Both fronts must resolve auto-* to the same concrete engine.
        assert_eq!(remote.backend, reference.backend, "sample {i} backend diverges");
    }

    router.shutdown();
    local.shutdown();
    for s in shards {
        s.shutdown();
    }
}

#[test]
fn counters_are_conserved_across_the_process_boundary() {
    let fx = fixture();
    let (shards, addrs) = fx.spawn_cluster(2);
    let router = RemoteCoordinator::connect(&addrs, 2, 0).unwrap();

    let n = 120usize;
    let mut ok = 0u64;
    for i in 0..n {
        let x = &fx.dataset.features[i % fx.dataset.len()];
        if router.infer(x, NATIVE[i % NATIVE.len()]).is_ok() {
            ok += 1;
        }
    }
    assert_eq!(ok, n as u64, "loopback cluster with idle queues must serve everything");

    // Shard-side conservation, summed over the wire from both
    // processes' raw counters.
    let cluster = router.cluster_stats().unwrap();
    assert_eq!(cluster.submitted, n as u64);
    assert_eq!(
        cluster.submitted,
        cluster.completed + cluster.rejected + cluster.failed,
        "shard-side counters leak across the process boundary"
    );
    // Exact latency aggregation: every completed request's sample ring
    // entry survived the trip.
    assert_eq!(cluster.latency_us.as_ref().map(|l| l.count), Some(n));

    // Router-side conservation.
    let rs = router.router_stats();
    assert_eq!(rs.submitted, n as u64);
    assert_eq!(rs.submitted, rs.completed + rs.rejected + rs.failed);

    router.shutdown();
    for s in shards {
        s.shutdown();
    }
}

#[test]
fn killing_a_shard_degrades_service_and_recovery_reintegrates_it() {
    let fx = fixture();
    let (mut shards, addrs) = fx.spawn_cluster(2);
    let router = RemoteCoordinator::connect(&addrs, 2, 50).unwrap();

    // Warm stream: everything works.
    for i in 0..20 {
        let x = &fx.dataset.features[i % fx.dataset.len()];
        router.infer(x, Backend::AutoMulticlass).unwrap();
    }

    // Kill shard 1 abruptly (no drain): its listener and connections
    // drop mid-stream.
    let killed_addr = addrs[1].clone();
    shards.remove(1).shutdown();

    // The stream must keep serving every request — the ring walk fails
    // over to shard 0 on transport errors.
    for i in 0..40 {
        let x = &fx.dataset.features[i % fx.dataset.len()];
        let r = router.infer(x, Backend::AutoMulticlass);
        assert!(r.is_ok(), "request {i} failed during single-shard outage: {r:?}");
    }
    assert!(router.failovers() > 0, "a two-shard ring must have routed around the dead shard");
    wait_for("heartbeat to flag the dead shard", Duration::from_secs(5), || {
        !router.healthy_shards()[1]
    });

    // Restart the shard on the same address: the heartbeat must
    // reintegrate it without touching the router.
    let server = CoordinatorServer::from_compiled_artifacts(&fx.cfg, fx.cmc.clone(), fx.cco.clone())
        .unwrap();
    let revived = ShardServer::bind(server, &killed_addr).unwrap();
    wait_for("heartbeat to reintegrate the revived shard", Duration::from_secs(10), || {
        router.healthy_shards()[1]
    });
    for i in 0..20 {
        let x = &fx.dataset.features[i % fx.dataset.len()];
        router.infer(x, Backend::AutoCotm).unwrap();
    }
    // Router-side conservation held through the outage and recovery.
    let rs = router.router_stats();
    assert_eq!(rs.submitted, rs.completed + rs.rejected + rs.failed);

    router.shutdown();
    revived.shutdown();
    for s in shards {
        s.shutdown();
    }
}

#[test]
fn adversarial_bytes_cannot_crash_or_hang_a_shard() {
    let fx = fixture();
    let shard = fx.spawn_shard();
    let addr = shard.local_addr();

    // 1. Wrong magic.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    expect_closed(s);

    // 2. Wrong version.
    let mut s = TcpStream::connect(addr).unwrap();
    let mut frame = Msg::Heartbeat { nonce: 1 }.encode_frame().unwrap();
    frame[4] = 9;
    s.write_all(&frame).unwrap();
    expect_closed(s);

    // 3. Oversized length prefix (shard must not allocate or block).
    let mut s = TcpStream::connect(addr).unwrap();
    let mut header = Vec::from(MAGIC);
    header.push(VERSION);
    header.push(5);
    header.extend_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
    assert_eq!(header.len(), HEADER_LEN);
    s.write_all(&header).unwrap();
    expect_closed(s);

    // 4. Unknown message type.
    let mut s = TcpStream::connect(addr).unwrap();
    let mut frame = Msg::Drain.encode_frame().unwrap();
    frame[5] = 0xEE;
    s.write_all(&frame).unwrap();
    expect_closed(s);

    // 5. Truncated frame then disconnect (client dies mid-send).
    let mut s = TcpStream::connect(addr).unwrap();
    let frame = Msg::Heartbeat { nonce: 2 }.encode_frame().unwrap();
    s.write_all(&frame[..frame.len() - 3]).unwrap();
    drop(s);

    // Malformed traffic was counted, and the shard still serves a
    // well-formed client afterwards.
    wait_for("protocol errors to be counted", Duration::from_secs(5), || {
        shard.protocol_errors() >= 4
    });
    let mut s = TcpStream::connect(addr).unwrap();
    Msg::Heartbeat { nonce: 7 }.write_to(&mut s).unwrap();
    assert_eq!(Msg::read_from(&mut s).unwrap(), Msg::HeartbeatAck { nonce: 7 });

    shard.shutdown();
}

fn expect_closed(mut s: TcpStream) {
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 64];
    // The shard answers garbage by closing; EOF (Ok(0)) or a reset
    // both prove it did not hang. A timeout fails the test.
    match s.read(&mut buf) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("shard answered garbage with {n} bytes instead of closing"),
    }
}

#[test]
fn one_connection_interleaves_heartbeats_stats_and_inference() {
    let fx = fixture();
    let shard = fx.spawn_shard();
    let mut s = TcpStream::connect(shard.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    let x = &fx.dataset.features[0];
    for round in 0..5u64 {
        Msg::Heartbeat { nonce: round }.write_to(&mut s).unwrap();
        assert_eq!(Msg::read_from(&mut s).unwrap(), Msg::HeartbeatAck { nonce: round });

        Msg::InferRequest {
            backend: "bitpar-multiclass".into(),
            features: x.clone(),
        }
        .write_to(&mut s)
        .unwrap();
        match Msg::read_from(&mut s).unwrap() {
            Msg::InferResponse { backend, class_sums, .. } => {
                assert_eq!(backend, "bitpar-multiclass");
                assert!(!class_sums.is_empty());
            }
            other => panic!("round {round}: unexpected reply {other:?}"),
        }

        Msg::StatsRequest.write_to(&mut s).unwrap();
        match Msg::read_from(&mut s).unwrap() {
            Msg::StatsReply { submitted, completed, rejected, failed, .. } => {
                assert_eq!(submitted, round + 1);
                assert_eq!(submitted, completed + rejected + failed);
            }
            other => panic!("round {round}: unexpected stats reply {other:?}"),
        }
    }

    // Unknown backend: a clean wire-level failure, connection stays up.
    Msg::InferRequest { backend: "no-such-engine".into(), features: x.clone() }
        .write_to(&mut s)
        .unwrap();
    match Msg::read_from(&mut s).unwrap() {
        Msg::Failed { reason } => assert!(reason.contains("no-such-engine"), "{reason}"),
        other => panic!("unexpected reply {other:?}"),
    }
    // Wrong feature width: propagated as Failed, not a crash.
    Msg::InferRequest { backend: "bitpar-multiclass".into(), features: vec![true; 3] }
        .write_to(&mut s)
        .unwrap();
    match Msg::read_from(&mut s).unwrap() {
        Msg::Failed { reason } => assert!(reason.contains("feature width"), "{reason}"),
        other => panic!("unexpected reply {other:?}"),
    }

    shard.shutdown();
}

#[test]
fn backpressure_is_propagated_not_swallowed() {
    let fx = fixture();
    let cfg = ServeConfig { queue_depth: 1, ..fx.cfg.clone() };
    let server =
        CoordinatorServer::from_compiled_artifacts(&cfg, fx.cmc.clone(), fx.cco.clone()).unwrap();
    let shard = ShardServer::bind(server, "127.0.0.1:0").unwrap();
    let addr = shard.local_addr();

    // Hammer a queue_depth=1 shard from several connections at once:
    // overlapping submissions must surface as wire-level rejections
    // carrying the coordinator's own backpressure message.
    let rejections = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let served = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let x = fx.dataset.features[0].clone();
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let (rejections, served, x) = (Arc::clone(&rejections), Arc::clone(&served), x.clone());
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                for _ in 0..200 {
                    if rejections.load(Ordering::Relaxed) > 0 {
                        return;
                    }
                    Msg::InferRequest { backend: "bitpar-multiclass".into(), features: x.clone() }
                        .write_to(&mut s)
                        .unwrap();
                    match Msg::read_from(&mut s).unwrap() {
                        Msg::InferResponse { .. } => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Msg::Reject { reason } => {
                            assert!(reason.contains("backpressure"), "{reason}");
                            rejections.fetch_add(1, Ordering::Relaxed);
                        }
                        Msg::Failed { reason } => panic!("unexpected failure: {reason}"),
                        other => panic!("unexpected reply {other:?}"),
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert!(
        rejections.load(Ordering::Relaxed) > 0,
        "4 writers against queue_depth=1 never collided ({} served)",
        served.load(Ordering::Relaxed)
    );
    shard.shutdown();
}

#[test]
fn drain_is_graceful_and_acknowledged() {
    let fx = fixture();
    let (shards, addrs) = fx.spawn_cluster(2);
    let router = RemoteCoordinator::connect(&addrs, 1, 0).unwrap();

    for i in 0..10 {
        router.infer(&fx.dataset.features[i], NATIVE[i % NATIVE.len()]).unwrap();
    }
    assert_eq!(router.drain(), 2, "every shard must ack the drain");
    for s in &shards {
        wait_for("shard to stop after drain", Duration::from_secs(5), || s.is_stopped());
    }
    router.shutdown();
    for s in shards {
        s.shutdown();
    }
}
