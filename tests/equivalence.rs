//! §III-A functional verification as an integration test: *"all
//! logically equivalent TM implementations achieve identical inference
//! accuracy"* — every hardware architecture, on randomly generated
//! models and on trained Iris models, must agree with the software
//! reference (up to WTA ties among equal maximisers, and documented
//! LOD quantisation for the CoTM race on near-ties).

use tsetlin_td::arch::digital::{
    async_bd_cotm, async_bd_multiclass, sync_cotm, sync_multiclass,
};
use tsetlin_td::arch::proposed_cotm::ProposedCotm;
use tsetlin_td::arch::proposed_tm::ProposedMulticlass;
use tsetlin_td::arch::Architecture;
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::infer::{
    cotm_class_sums, multiclass_class_sums, predict_argmax,
};
use tsetlin_td::tm::{data, ClauseMask, CoTmModel, MultiClassTmModel, TmParams};
use tsetlin_td::wta::WtaKind;

fn random_multiclass(g: &mut Gen, f: usize, c: usize, k: usize) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = MultiClassTmModel::zeroed(p);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = ClauseMask {
                include: (0..2 * f).map(|_| g.chance(0.25)).collect(),
            };
        }
    }
    m
}

fn random_cotm(g: &mut Gen, f: usize, c: usize, k: usize) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = CoTmModel::zeroed(p.clone());
    for clause in &mut m.clauses {
        *clause = ClauseMask {
            include: (0..2 * f).map(|_| g.chance(0.25)).collect(),
        };
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = g.i64(-(p.max_weight as i64)..p.max_weight as i64 + 1) as i32;
        }
    }
    m
}

#[test]
fn digital_multiclass_archs_match_reference_on_random_models() {
    prop("digital multiclass equivalence", 25, |g| {
        let f = g.usize(2..10);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let mut s = sync_multiclass(m.clone());
        let mut a = async_bd_multiclass(m.clone());
        for _ in 0..5 {
            let x = g.bools(f);
            let want = multiclass_class_sums(&m, &x);
            assert_eq!(s.infer(&x).unwrap().class_sums, want);
            assert_eq!(a.infer(&x).unwrap().class_sums, want);
            assert_eq!(s.infer(&x).unwrap().predicted, predict_argmax(&want));
        }
    });
}

#[test]
fn proposed_multiclass_picks_a_maximiser_on_random_models() {
    prop("proposed multiclass argmax", 15, |g| {
        let f = g.usize(2..8);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let mut hw = ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap();
        for _ in 0..4 {
            let x = g.bools(f);
            let sums = multiclass_class_sums(&m, &x);
            let r = hw.infer(&x).unwrap();
            assert_eq!(r.class_sums, sums);
            // The Hamming race is linear-exact: the winner must be one
            // of the maximisers.
            let best = *sums.iter().max().unwrap();
            assert_eq!(
                sums[r.predicted], best,
                "x={x:?} sums={sums:?} predicted={}",
                r.predicted
            );
        }
    });
}

#[test]
fn digital_cotm_archs_match_reference_on_random_models() {
    prop("digital cotm equivalence", 25, |g| {
        let f = g.usize(2..10);
        let c = g.usize(2..12);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let mut s = sync_cotm(m.clone());
        let mut a = async_bd_cotm(m.clone());
        for _ in 0..5 {
            let x = g.bools(f);
            let want = cotm_class_sums(&m, &x);
            assert_eq!(s.infer(&x).unwrap().class_sums, want);
            assert_eq!(a.infer(&x).unwrap().class_sums, want);
        }
    });
}

#[test]
fn proposed_cotm_near_argmax_on_random_models() {
    // The LOD-compressed race is documented to deviate only on near-ties
    // / cross-scale cases; require the winner to be within 2 of the true
    // maximum (measured slack: quantisation of one TDC code) and exact
    // sums reporting.
    prop("proposed cotm near-argmax", 10, |g| {
        let f = g.usize(2..8);
        let c = g.usize(2..10);
        let k = g.usize(2..4);
        let m = random_cotm(g, f, c, k);
        let mut hw = ProposedCotm::new(m.clone(), WtaKind::Tba).unwrap();
        for _ in 0..3 {
            let x = g.bools(f);
            let sums = cotm_class_sums(&m, &x);
            let r = hw.infer(&x).unwrap();
            assert_eq!(r.class_sums, sums);
            let best = *sums.iter().max().unwrap();
            assert!(
                sums[r.predicted] >= best - 2,
                "x={x:?} sums={sums:?} predicted={}",
                r.predicted
            );
        }
    });
}

#[test]
fn all_six_reach_iris_accuracy() {
    // The end criterion of §III-A: identical accuracy on the benchmark.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = tsetlin_td::tm::cotm_train::train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();
    let mut archs: Vec<Box<dyn Architecture>> = vec![
        Box::new(sync_multiclass(m.clone())),
        Box::new(async_bd_multiclass(m.clone())),
        Box::new(ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap()),
        Box::new(sync_cotm(cm.clone())),
        Box::new(async_bd_cotm(cm.clone())),
        Box::new(ProposedCotm::new(cm, WtaKind::Tba).unwrap()),
    ];
    for a in archs.iter_mut() {
        let correct = d
            .features
            .iter()
            .zip(&d.labels)
            .filter(|(x, &y)| a.infer(x).unwrap().predicted == y)
            .count();
        let acc = correct as f64 / d.len() as f64;
        assert!(acc >= 0.90, "{}: accuracy {acc:.3}", a.name());
    }
}

#[test]
fn bitparallel_front_door_serves_random_models_concurrently() {
    // The serving plumbing (submit -> dynamic batcher -> shared
    // bit-parallel engine -> relay) must not corrupt results: random
    // models, concurrent mixed submissions through the coordinator's
    // Backend::BitParallel* front door, bit-exact sums out.
    use tsetlin_td::config::ServeConfig;
    use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};

    prop("bitparallel front door", 5, |g| {
        let f = g.usize(2..12);
        let c = 2 * g.usize(1..4);
        let k = g.usize(2..4);
        let m = random_multiclass(g, f, c, k);
        let cm = random_cotm(g, f, c, k);
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 16,
            ..ServeConfig::default()
        };
        let srv = CoordinatorServer::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        let samples: Vec<Vec<bool>> = (0..48).map(|_| g.bools(f)).collect();
        let pending: Vec<_> = samples
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let backend = if i % 2 == 0 {
                    Backend::BitParallelMulticlass
                } else {
                    Backend::BitParallelCotm
                };
                (i, backend, srv.submit(InferRequest { features: x.clone(), backend }).unwrap())
            })
            .collect();
        for (i, backend, rx) in pending {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .expect("reply within deadline")
                .expect("bit-parallel request served");
            assert_eq!(r.backend, backend);
            let want = if backend == Backend::BitParallelMulticlass {
                multiclass_class_sums(&m, &samples[i])
            } else {
                cotm_class_sums(&cm, &samples[i])
            };
            assert_eq!(r.class_sums, want, "request {i} via {backend:?}");
            assert_eq!(r.predicted, predict_argmax(&want), "request {i}");
        }
        srv.shutdown();
    });
}

#[test]
fn sharded_front_door_serves_random_models_concurrently() {
    // The scale-out plumbing (consistent-hash routing -> per-shard
    // coordinator -> dynamic batcher -> shared bit-parallel engine,
    // relay-free replies) must not corrupt results: random models,
    // concurrent mixed submissions through the sharded front door,
    // bit-exact sums out, and counters that aggregate across shards.
    use tsetlin_td::config::ServeConfig;
    use tsetlin_td::coordinator::{Backend, InferRequest, ShardedCoordinator};

    prop("sharded front door", 4, |g| {
        let f = g.usize(2..12);
        let c = 2 * g.usize(1..4);
        let k = g.usize(2..4);
        let m = random_multiclass(g, f, c, k);
        let cm = random_cotm(g, f, c, k);
        let cfg = ServeConfig {
            shards: 3,
            workers: 1,
            max_batch: 16,
            ..ServeConfig::default()
        };
        let srv = ShardedCoordinator::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        let samples: Vec<Vec<bool>> = (0..60).map(|_| g.bools(f)).collect();
        // Routing must be deterministic before, during, and after load.
        let routes: Vec<usize> =
            samples.iter().map(|x| srv.shard_for_features(x)).collect();
        let pending: Vec<_> = samples
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let backend = if i % 2 == 0 {
                    Backend::BitParallelMulticlass
                } else {
                    Backend::BitParallelCotm
                };
                (
                    i,
                    backend,
                    srv.submit(InferRequest { features: x.clone(), backend }).unwrap(),
                )
            })
            .collect();
        for (i, backend, rx) in pending {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .expect("reply within deadline")
                .expect("sharded request served");
            assert_eq!(r.backend, backend);
            let want = if backend == Backend::BitParallelMulticlass {
                multiclass_class_sums(&m, &samples[i])
            } else {
                cotm_class_sums(&cm, &samples[i])
            };
            assert_eq!(r.class_sums, want, "request {i} via {backend:?}");
            assert_eq!(r.predicted, predict_argmax(&want), "request {i}");
        }
        for (x, &route) in samples.iter().zip(&routes) {
            assert_eq!(srv.shard_for_features(x), route, "routing drifted under load");
        }
        // Conservation across the shard set: nothing lost, nothing
        // double-counted, and per-shard counters sum to the aggregate.
        let agg = srv.stats();
        assert_eq!(agg.submitted, 60);
        assert_eq!(agg.completed, 60);
        assert_eq!(agg.failed, 0);
        let per_shard = srv.shard_stats();
        assert_eq!(per_shard.iter().map(|s| s.completed).sum::<u64>(), 60);
        for (s, snap) in per_shard.iter().enumerate() {
            let routed = routes.iter().filter(|&&r| r == s).count() as u64;
            assert_eq!(snap.submitted, routed, "shard {s} submitted count");
        }
        srv.shutdown();
    });
}

#[test]
fn indexed_and_auto_front_door_serve_sharded_bit_exact() {
    // The event-driven inverted-index tier through the full serving
    // stack: sharded front door -> per-shard dynamic batcher -> shared
    // indexed engine, mixed with auto-selected requests. Sums must be
    // bit-exact against the scalar reference whichever engine serves,
    // and auto replies must name the concrete engine that did.
    use tsetlin_td::config::ServeConfig;
    use tsetlin_td::coordinator::{Backend, InferRequest, ShardedCoordinator};

    prop("indexed front door", 4, |g| {
        let f = g.usize(2..12);
        let c = 2 * g.usize(1..4);
        let k = g.usize(2..4);
        let m = random_multiclass(g, f, c, k);
        let cm = random_cotm(g, f, c, k);
        // Random threshold exercises both auto resolutions across
        // cases; outputs must be invariant to it.
        let threshold = if g.bool() { 1.0 } else { 0.0 };
        let cfg = ServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 16,
            indexed_density_threshold: threshold,
            ..ServeConfig::default()
        };
        let srv = ShardedCoordinator::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        let backends = [
            Backend::IndexedMulticlass,
            Backend::IndexedCotm,
            Backend::AutoMulticlass,
            Backend::AutoCotm,
        ];
        let samples: Vec<Vec<bool>> = (0..48).map(|_| g.bools(f)).collect();
        let pending: Vec<_> = samples
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let backend = backends[i % backends.len()];
                (
                    i,
                    backend,
                    srv.submit(InferRequest { features: x.clone(), backend }).unwrap(),
                )
            })
            .collect();
        for (i, backend, rx) in pending {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .expect("reply within deadline")
                .expect("indexed/auto request served");
            // The reply names a concrete native engine: the requested
            // backend itself for indexed-*, the resolved engine for
            // auto-* (auto is a routing alias, never a serving tier).
            assert!(r.backend.is_native_batched(), "request {i} via {backend:?}");
            if backend.is_indexed() {
                assert_eq!(r.backend, backend);
            }
            let multiclass = matches!(
                backend,
                Backend::IndexedMulticlass | Backend::AutoMulticlass
            );
            let want = if multiclass {
                multiclass_class_sums(&m, &samples[i])
            } else {
                cotm_class_sums(&cm, &samples[i])
            };
            assert_eq!(r.class_sums, want, "request {i} via {backend:?}");
            assert_eq!(r.predicted, predict_argmax(&want), "request {i}");
        }
        let agg = srv.stats();
        assert_eq!(agg.submitted, 48);
        assert_eq!(agg.completed, 48);
        assert_eq!(agg.failed, 0);
        srv.shutdown();
    });
}

#[test]
fn compressed_and_auto_front_door_serve_sharded_bit_exact() {
    // The ETHEREAL compressed tier through the full serving stack:
    // sharded front door -> per-shard dynamic batcher -> shared
    // compressed engine, mixed with three-way auto-selected requests.
    // Sums must be bit-exact against the scalar reference whichever
    // engine serves, counters must conserve per shard, and auto
    // replies must name the concrete engine that served them.
    use tsetlin_td::config::ServeConfig;
    use tsetlin_td::coordinator::{Backend, InferRequest, ShardedCoordinator};

    prop("compressed front door", 4, |g| {
        let f = g.usize(2..12);
        let c = 2 * g.usize(1..4);
        let k = g.usize(2..4);
        let m = random_multiclass(g, f, c, k);
        let cm = random_cotm(g, f, c, k);
        // Random threshold pair drives auto to all three resolutions
        // across cases; outputs must be invariant to it.
        let indexed_t = if g.bool() { 1.0 } else { 0.0 };
        let compressed_t = if g.bool() { 1.0 } else { 0.0 };
        let cfg = ServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 16,
            indexed_density_threshold: indexed_t,
            compressed_density_threshold: compressed_t,
            ..ServeConfig::default()
        };
        let srv = ShardedCoordinator::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        let backends = [
            Backend::CompressedMulticlass,
            Backend::CompressedCotm,
            Backend::AutoMulticlass,
            Backend::AutoCotm,
        ];
        let samples: Vec<Vec<bool>> = (0..48).map(|_| g.bools(f)).collect();
        let routes: Vec<usize> =
            samples.iter().map(|x| srv.shard_for_features(x)).collect();
        let pending: Vec<_> = samples
            .iter()
            .enumerate()
            .map(|(i, x)| {
                let backend = backends[i % backends.len()];
                (
                    i,
                    backend,
                    srv.submit(InferRequest { features: x.clone(), backend }).unwrap(),
                )
            })
            .collect();
        for (i, backend, rx) in pending {
            let r = rx
                .recv_timeout(std::time::Duration::from_secs(30))
                .expect("reply within deadline")
                .expect("compressed/auto request served");
            assert!(r.backend.is_native_batched(), "request {i} via {backend:?}");
            if backend.is_compressed() {
                assert_eq!(r.backend, backend);
            }
            let multiclass = matches!(
                backend,
                Backend::CompressedMulticlass | Backend::AutoMulticlass
            );
            let want = if multiclass {
                multiclass_class_sums(&m, &samples[i])
            } else {
                cotm_class_sums(&cm, &samples[i])
            };
            assert_eq!(r.class_sums, want, "request {i} via {backend:?}");
            assert_eq!(r.predicted, predict_argmax(&want), "request {i}");
        }
        // Conservation across the shard set, per shard.
        let agg = srv.stats();
        assert_eq!(agg.submitted, 48);
        assert_eq!(agg.completed, 48);
        assert_eq!(agg.failed, 0);
        let per_shard = srv.shard_stats();
        assert_eq!(per_shard.iter().map(|s| s.completed).sum::<u64>(), 48);
        for (s, snap) in per_shard.iter().enumerate() {
            let routed = routes.iter().filter(|&&r| r == s).count() as u64;
            assert_eq!(snap.submitted, routed, "shard {s} submitted count");
        }
        srv.shutdown();
    });
}

#[test]
fn wta_choice_does_not_change_multiclass_results() {
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 40, 2).unwrap();
    let mut tba = ProposedMulticlass::new(m.clone(), WtaKind::Tba).unwrap();
    let mut mesh = ProposedMulticlass::new(m.clone(), WtaKind::Mesh).unwrap();
    for x in d.features.iter().take(50) {
        let a = tba.infer(x).unwrap();
        let b = mesh.infer(x).unwrap();
        // Equal-maximiser tolerance on exact race ties.
        assert_eq!(a.class_sums[a.predicted], b.class_sums[b.predicted]);
    }
}
