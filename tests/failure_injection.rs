//! Failure injection: the system must *detect* broken protocols, reject
//! malformed inputs, and fail closed — not wedge or silently corrupt.

use tsetlin_td::async_ctrl::handshake::{Counters, FourPhaseMonitor, TwoPhaseMonitor};
use tsetlin_td::config::{Json, ServeConfig, TomlDoc};
use tsetlin_td::sim::energy::TechParams;
use tsetlin_td::sim::{Circuit, Logic, Time};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::{serde as tmserde, ClauseMask, MultiClassTmModel, TmParams};

// ------------------------------------------------------------ protocol

#[test]
fn two_phase_monitor_catches_injected_double_req() {
    prop("2-phase violation detection", 20, |g| {
        let mut c = Circuit::new(TechParams::tsmc65_digital());
        let req = c.net_init("req", Logic::Zero);
        let ack = c.net_init("ack", Logic::Zero);
        let ctr = Counters::new();
        c.add(
            Box::new(TwoPhaseMonitor::new("mon", req, ack, ctr.clone())),
            vec![req, ack],
        );
        // Legal prefix of random length.
        let legal = g.usize(0..4);
        let mut t = Time::ps(10);
        for i in 0..legal {
            let v = if i % 2 == 0 { Logic::One } else { Logic::Zero };
            c.drive(req, v, t);
            t += Time::ps(10);
            c.drive(ack, v, t);
            t += Time::ps(10);
        }
        // Inject: two req transitions with no intervening ack.
        let v1 = if legal % 2 == 0 { Logic::One } else { Logic::Zero };
        c.drive(req, v1, t);
        c.drive(req, v1.not(), t + Time::ps(10));
        c.run_to_quiescence().unwrap();
        assert!(ctr.violations.get() >= 1, "violation not detected");
    });
}

#[test]
fn four_phase_monitor_catches_rtz_skip() {
    let mut c = Circuit::new(TechParams::tsmc65_digital());
    let req = c.net_init("req", Logic::Zero);
    let ack = c.net_init("ack", Logic::Zero);
    let ctr = Counters::new();
    c.add(
        Box::new(FourPhaseMonitor::new("mon", req, ack, ctr.clone())),
        vec![req, ack],
    );
    // req↑ ack↑ then req↑... impossible (no RTZ) — emulate glitchy
    // requester re-raising by dropping/raising within one ack phase.
    c.drive(req, Logic::One, Time::ps(10));
    c.drive(ack, Logic::One, Time::ps(20));
    c.drive(req, Logic::Zero, Time::ps(30));
    c.drive(req, Logic::One, Time::ps(40)); // ack still high: violation
    c.run_to_quiescence().unwrap();
    assert!(ctr.violations.get() >= 1);
}

// ------------------------------------------------------------ simulator

#[test]
fn oscillation_trips_max_events_instead_of_hanging() {
    use tsetlin_td::gates::basic::{Gate, GateOp};
    let tech = TechParams::tsmc65_digital();
    let mut c = Circuit::new(tech.clone());
    let n = c.net("ring");
    // Inverter feeding itself = unbounded oscillation.
    c.add(
        Box::new(Gate::new("inv", GateOp::Inv, vec![n], n, &tech)),
        vec![n],
    );
    c.max_events = 10_000;
    c.drive(n, Logic::Zero, Time::ZERO);
    let err = c.run_to_quiescence().unwrap_err();
    assert!(err.to_string().contains("max_events"));
}

#[test]
fn scheduling_into_the_past_is_rejected() {
    let mut c = Circuit::new(TechParams::tsmc65_digital());
    let n = c.net("n");
    c.drive(n, Logic::One, Time::ps(100));
    c.run_to_quiescence().unwrap();
    assert!(c.drive_at(n, Logic::Zero, Time::ps(50)).is_err());
}

// ------------------------------------------------------------- parsers

#[test]
fn corrupted_model_files_are_rejected_not_misparsed() {
    let p = TmParams {
        features: 4,
        clauses: 4,
        classes: 2,
        ..TmParams::iris_paper()
    };
    let mut m = MultiClassTmModel::zeroed(p);
    m.clauses[0][0] = ClauseMask { include: vec![true, false, true, false, false, false, false, false] };
    let text = tmserde::multiclass_to_string(&m);
    prop("model corruption rejected or harmless", 60, |g| {
        // Flip one byte into a random printable character.
        let mut bytes = text.clone().into_bytes();
        let idx = g.usize(0..bytes.len());
        bytes[idx] = *g.pick(b"xyz5201[]= ");
        let corrupted = String::from_utf8_lossy(&bytes).to_string();
        match tmserde::multiclass_from_str(&corrupted) {
            // Either a clean parse error...
            Err(_) => {}
            // ...or a still-valid model (the byte hit an innocuous spot);
            // in that case it must pass its own validation.
            Ok(parsed) => parsed.validate().unwrap(),
        }
    });
}

#[test]
fn json_parser_rejects_malformed_manifests() {
    for bad in [
        "",
        "{",
        "{\"a\": }",
        "[1, 2,",
        "{\"a\": 1} trailing",
        "{\"a\": 0x10}",
        "\"unterminated",
    ] {
        assert!(Json::parse(bad).is_err(), "accepted: {bad:?}");
    }
}

#[test]
fn toml_parser_rejects_malformed_configs() {
    for bad in ["[open\n", "key\n", "k = \"unterminated\n", "k = 1 2\n"] {
        assert!(TomlDoc::parse(bad).is_err(), "accepted: {bad:?}");
    }
}

#[test]
fn serve_config_validation_fails_closed() {
    // Degenerate configs must be refused before any thread spawns.
    let bad = ServeConfig { workers: 0, ..ServeConfig::default() };
    assert!(bad.validate().is_err());
    let bad = ServeConfig { max_batch: 0, ..ServeConfig::default() };
    assert!(bad.validate().is_err());
    let bad = ServeConfig { queue_depth: 1, max_batch: 64, ..ServeConfig::default() };
    assert!(bad.validate().is_err());
}

// ---------------------------------------------------------- model edge

#[test]
fn architectures_reject_wrong_feature_width() {
    use tsetlin_td::arch::digital::sync_multiclass;
    use tsetlin_td::arch::Architecture;
    let p = TmParams { features: 8, clauses: 4, classes: 2, ..TmParams::iris_paper() };
    let m = MultiClassTmModel::zeroed(p);
    let mut a = sync_multiclass(m);
    assert!(a.infer(&[true; 3]).is_err());
    assert!(a.infer(&[true; 9]).is_err());
    // Correct width still works after the failures (no state corruption).
    assert!(a.infer(&[false; 8]).is_ok());
}

#[test]
fn degenerate_tm_params_rejected() {
    let bad = TmParams { clauses: 0, ..TmParams::iris_paper() };
    assert!(bad.validate().is_err());
    let bad = TmParams { classes: 1, ..TmParams::iris_paper() };
    assert!(bad.validate().is_err());
    // Odd clause counts only break the multi-class (polarity-paired) variant.
    let odd = TmParams { clauses: 7, ..TmParams::iris_paper() };
    assert!(odd.validate().is_ok());
    assert!(MultiClassTmModel::zeroed(odd).validate().is_err());
}
