//! Differential conformance suite for the native batched inference
//! engines (§III-A: *"all logically equivalent TM implementations
//! achieve identical inference accuracy"* — and for these backends we
//! demand more: identical class sums, sample by sample).
//!
//! Every property here compares an engine tier against the scalar
//! reference `tm::infer` on randomly generated models: the packed
//! bit-parallel engines (`tm::fast_infer`) and the event-driven
//! inverted-index engines (`tm::index`) are held to the same bar, and
//! the density-based auto-selection is checked to change only *which*
//! engine computes, never the sums. Feature widths deliberately
//! straddle the packed-word boundaries (a feature width of 32 is
//! exactly one 64-literal word; 33 spills into a tail word whose
//! padding must stay masked), clause densities range from all-exclude
//! (empty clause) to near-full, and batch sizes cross the 64-sample
//! block boundary of the bit-sliced layout.

use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::index::{prefer_indexed, PACKED_VS_INDEXED_DENSITY};
use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums, predict_argmax};
use tsetlin_td::tm::{
    data, BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    IndexedCotm, IndexedMulticlass, MultiClassTmModel, TmParams,
};

/// Feature widths that exercise word-boundary packing: one literal word
/// (F ≤ 32), exact boundaries (F = 32 → 64 literals, F = 64 → 128), and
/// the off-by-one tail-word cases around them. 64 and 65 are the
/// boundary pair called out in the issue; 31/32/33 are the same
/// boundary in literal space.
const BOUNDARY_WIDTHS: [usize; 10] = [1, 5, 31, 32, 33, 63, 64, 65, 97, 130];

fn draw_features(g: &mut Gen) -> usize {
    if g.chance(0.6) {
        *g.pick(&BOUNDARY_WIDTHS)
    } else {
        g.usize(1..200)
    }
}

/// Clause density: includes empty (all-exclude) clauses with real
/// probability so the "empty clause fires never" convention is hit.
fn draw_density(g: &mut Gen) -> f64 {
    if g.chance(0.15) {
        0.0
    } else {
        0.02 + 0.4 * g.f64_unit()
    }
}

fn random_multiclass(g: &mut Gen, f: usize, c: usize, k: usize) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = MultiClassTmModel::zeroed(p);
    let density = draw_density(g);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = ClauseMask {
                include: (0..2 * f).map(|_| g.chance(density)).collect(),
            };
        }
    }
    m
}

fn random_cotm(g: &mut Gen, f: usize, c: usize, k: usize) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = CoTmModel::zeroed(p.clone());
    let density = draw_density(g);
    for clause in &mut m.clauses {
        *clause = ClauseMask {
            include: (0..2 * f).map(|_| g.chance(density)).collect(),
        };
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = g.i64(-(p.max_weight as i64)..p.max_weight as i64 + 1) as i32;
        }
    }
    m
}

#[test]
fn multiclass_single_sample_bit_exact_on_random_models() {
    // 120 random models (incl. non-multiple-of-64 literal widths): class
    // sums and argmax must be bit-exact against the scalar reference.
    prop("bitparallel multiclass single-sample", 120, |g| {
        let f = draw_features(g);
        let c = 2 * g.usize(1..7);
        let k = g.usize(2..6);
        let m = random_multiclass(g, f, c, k);
        let e = BitParallelMulticlass::from_model(&m).unwrap();
        for _ in 0..4 {
            let x = g.bools(f);
            let want = multiclass_class_sums(&m, &x);
            assert_eq!(e.class_sums(&x), want, "f={f} c={c} k={k}");
            assert_eq!(e.predict(&x), predict_argmax(&want));
        }
    });
}

#[test]
fn cotm_single_sample_bit_exact_on_random_models() {
    prop("bitparallel cotm single-sample", 120, |g| {
        let f = draw_features(g);
        let c = g.usize(1..14);
        let k = g.usize(2..6);
        let m = random_cotm(g, f, c, k);
        let e = BitParallelCotm::from_model(&m).unwrap();
        for _ in 0..4 {
            let x = g.bools(f);
            let want = cotm_class_sums(&m, &x);
            assert_eq!(e.class_sums(&x), want, "f={f} c={c} k={k}");
            assert_eq!(e.predict(&x), predict_argmax(&want));
        }
    });
}

#[test]
fn multiclass_batched_matches_reference_across_block_boundaries() {
    // Batch sizes straddling the 64-sample bit-slice blocks: every
    // per-sample result of the batched path must equal the scalar
    // reference, and the sharded variant must be a pure reordering.
    prop("bitparallel multiclass batched", 40, |g| {
        let f = draw_features(g).min(80);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let e = BitParallelMulticlass::from_model(&m).unwrap();
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 127, 128, 130]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let out = e.infer_batch(&rows);
        assert_eq!(out.len(), n);
        for (s, (sums, pred)) in out.iter().enumerate() {
            let want = multiclass_class_sums(&m, &rows[s]);
            assert_eq!(sums, &want, "sample {s}/{n} f={f}");
            assert_eq!(*pred, predict_argmax(&want), "sample {s}/{n}");
        }
        assert_eq!(e.infer_batch_sharded(&rows, 3), out);
    });
}

#[test]
fn cotm_batched_matches_reference_across_block_boundaries() {
    prop("bitparallel cotm batched", 40, |g| {
        let f = draw_features(g).min(80);
        let c = g.usize(1..10);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let e = BitParallelCotm::from_model(&m).unwrap();
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 130]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let out = e.infer_batch(&rows);
        for (s, (sums, pred)) in out.iter().enumerate() {
            let want = cotm_class_sums(&m, &rows[s]);
            assert_eq!(sums, &want, "sample {s}/{n} f={f}");
            assert_eq!(*pred, predict_argmax(&want));
        }
        assert_eq!(e.infer_batch_sharded(&rows, 3), out);
    });
}

#[test]
fn indexed_multiclass_single_sample_bit_exact_on_random_models() {
    // The inverted-index engine is held to the identical bar as the
    // packed engine: 120 random models including word-boundary widths
    // (31/32/33/63/64/65 — the index has no words, but the shared
    // sweep must hold everywhere the packed one does) and all-exclude
    // clause densities.
    prop("indexed multiclass single-sample", 120, |g| {
        let f = draw_features(g);
        let c = 2 * g.usize(1..7);
        let k = g.usize(2..6);
        let m = random_multiclass(g, f, c, k);
        let e = IndexedMulticlass::from_model(&m).unwrap();
        for _ in 0..4 {
            let x = g.bools(f);
            let want = multiclass_class_sums(&m, &x);
            assert_eq!(e.class_sums(&x), want, "f={f} c={c} k={k}");
            assert_eq!(e.predict(&x), predict_argmax(&want));
        }
    });
}

#[test]
fn indexed_cotm_single_sample_bit_exact_on_random_models() {
    prop("indexed cotm single-sample", 120, |g| {
        let f = draw_features(g);
        let c = g.usize(1..14);
        let k = g.usize(2..6);
        let m = random_cotm(g, f, c, k);
        let e = IndexedCotm::from_model(&m).unwrap();
        for _ in 0..4 {
            let x = g.bools(f);
            let want = cotm_class_sums(&m, &x);
            assert_eq!(e.class_sums(&x), want, "f={f} c={c} k={k}");
            assert_eq!(e.predict(&x), predict_argmax(&want));
        }
    });
}

#[test]
fn indexed_multiclass_batched_matches_reference_across_block_boundaries() {
    // Batch sizes straddling the 64-sample block: the indexed batch
    // path reuses one counter scratch across the whole batch, so any
    // restore bug shows up as sample-order-dependent sums; the sharded
    // variant must be a pure reordering.
    prop("indexed multiclass batched", 40, |g| {
        let f = draw_features(g).min(80);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let e = IndexedMulticlass::from_model(&m).unwrap();
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 127, 128, 130]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let out = e.infer_batch(&rows);
        assert_eq!(out.len(), n);
        for (s, (sums, pred)) in out.iter().enumerate() {
            let want = multiclass_class_sums(&m, &rows[s]);
            assert_eq!(sums, &want, "sample {s}/{n} f={f}");
            assert_eq!(*pred, predict_argmax(&want), "sample {s}/{n}");
        }
        assert_eq!(e.infer_batch_sharded(&rows, 3), out);
    });
}

#[test]
fn indexed_cotm_batched_matches_reference_across_block_boundaries() {
    prop("indexed cotm batched", 40, |g| {
        let f = draw_features(g).min(80);
        let c = g.usize(1..10);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let e = IndexedCotm::from_model(&m).unwrap();
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 130]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let out = e.infer_batch(&rows);
        for (s, (sums, pred)) in out.iter().enumerate() {
            let want = cotm_class_sums(&m, &rows[s]);
            assert_eq!(sums, &want, "sample {s}/{n} f={f}");
            assert_eq!(*pred, predict_argmax(&want));
        }
        assert_eq!(e.infer_batch_sharded(&rows, 3), out);
    });
}

#[test]
fn auto_select_choice_never_changes_outputs() {
    // Whatever `prefer_indexed` decides for a model — at the default
    // threshold or any other — both candidate engines produce identical
    // sums, so the selection is purely a speed decision. Random models
    // span densities on both sides of the default crossover.
    prop("auto-select output invariance", 60, |g| {
        let f = draw_features(g).min(80);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let cm = random_cotm(g, f, c, k);
        let bp_mc = BitParallelMulticlass::from_model(&m).unwrap();
        let ix_mc = IndexedMulticlass::from_model(&m).unwrap();
        let bp_co = BitParallelCotm::from_model(&cm).unwrap();
        let ix_co = IndexedCotm::from_model(&cm).unwrap();
        // Exercise the decision itself (it must be total and pure)...
        let _ = prefer_indexed(ix_mc.density(), PACKED_VS_INDEXED_DENSITY);
        let _ = prefer_indexed(ix_co.density(), PACKED_VS_INDEXED_DENSITY);
        // ...and prove it irrelevant to the outputs.
        for _ in 0..4 {
            let x = g.bools(f);
            assert_eq!(
                ix_mc.class_sums(&x),
                bp_mc.class_sums(&x),
                "multiclass engines disagree (f={f} c={c} k={k})"
            );
            assert_eq!(
                ix_co.class_sums(&x),
                bp_co.class_sums(&x),
                "cotm engines disagree (f={f} c={c} k={k})"
            );
            assert_eq!(ix_mc.class_sums(&x), multiclass_class_sums(&m, &x));
            assert_eq!(ix_co.class_sums(&x), cotm_class_sums(&cm, &x));
        }
    });
}

#[test]
fn indexed_trained_iris_models_are_bit_exact_end_to_end() {
    // Trainer-produced models through the indexed single-sample,
    // batched, and sharded paths — same bar as the packed engines.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = tsetlin_td::tm::cotm_train::train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();
    let e_mc = IndexedMulticlass::from_model(&m).unwrap();
    let e_co = IndexedCotm::from_model(&cm).unwrap();

    let batch_mc = e_mc.infer_batch(&d.features);
    let batch_co = e_co.infer_batch(&d.features);
    assert_eq!(e_mc.infer_batch_sharded(&d.features, 4), batch_mc);
    assert_eq!(e_co.infer_batch_sharded(&d.features, 4), batch_co);
    for (i, x) in d.features.iter().enumerate() {
        let want_mc = multiclass_class_sums(&m, x);
        assert_eq!(e_mc.class_sums(x), want_mc, "iris sample {i} (multiclass)");
        assert_eq!(batch_mc[i].0, want_mc, "iris sample {i} (multiclass batched)");
        assert_eq!(batch_mc[i].1, predict_argmax(&want_mc));

        let want_co = cotm_class_sums(&cm, x);
        assert_eq!(e_co.class_sums(x), want_co, "iris sample {i} (cotm)");
        assert_eq!(batch_co[i].0, want_co, "iris sample {i} (cotm batched)");
        assert_eq!(batch_co[i].1, predict_argmax(&want_co));
    }
}

#[test]
fn trained_iris_models_are_bit_exact_end_to_end() {
    // Not just random masks: models produced by the real trainers must
    // agree sample-for-sample on the paper's benchmark, through the
    // single-sample, batched, and sharded paths.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm = tsetlin_td::tm::cotm_train::train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();
    let e_mc = BitParallelMulticlass::from_model(&m).unwrap();
    let e_co = BitParallelCotm::from_model(&cm).unwrap();

    let batch_mc = e_mc.infer_batch(&d.features);
    let batch_co = e_co.infer_batch(&d.features);
    assert_eq!(e_mc.infer_batch_sharded(&d.features, 4), batch_mc);
    assert_eq!(e_co.infer_batch_sharded(&d.features, 4), batch_co);
    for (i, x) in d.features.iter().enumerate() {
        let want_mc = multiclass_class_sums(&m, x);
        assert_eq!(e_mc.class_sums(x), want_mc, "iris sample {i} (multiclass)");
        assert_eq!(batch_mc[i].0, want_mc, "iris sample {i} (multiclass batched)");
        assert_eq!(batch_mc[i].1, predict_argmax(&want_mc));

        let want_co = cotm_class_sums(&cm, x);
        assert_eq!(e_co.class_sums(x), want_co, "iris sample {i} (cotm)");
        assert_eq!(batch_co[i].0, want_co, "iris sample {i} (cotm batched)");
        assert_eq!(batch_co[i].1, predict_argmax(&want_co));
    }

    // Forced lane widths are interchangeable on the trained models too
    // (the full dispatch matrix lives in tests/simd_dispatch.rs).
    use tsetlin_td::tm::{SimdLevel, WordLanes};
    for level in SimdLevel::available() {
        let lanes = WordLanes::new(level).unwrap();
        assert_eq!(
            e_mc.clone().with_lanes(lanes).infer_batch(&d.features),
            batch_mc,
            "multiclass level {}",
            level.name()
        );
        assert_eq!(
            e_co.clone().with_lanes(lanes).infer_batch(&d.features),
            batch_co,
            "cotm level {}",
            level.name()
        );
    }
}
