//! Property-based tests on the coordinator invariants (DESIGN.md item
//! (c)): routing (responses come from the requested backend and carry
//! the right semantics), batching (no request lost, dropped or
//! duplicated across arbitrary batch/timeout configurations), and state
//! (counters are conserved under concurrent mixed load + backpressure).

use std::sync::Arc;
use std::time::Duration;

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::batcher::DynamicBatcher;
use tsetlin_td::coordinator::stats::ServerStats;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};

fn models() -> (tsetlin_td::tm::MultiClassTmModel, tsetlin_td::tm::CoTmModel, data::Dataset) {
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
    (m, cm, d)
}

#[test]
fn batcher_conserves_requests_under_random_configs() {
    prop("batcher conservation", 12, |g| {
        let max_batch = g.usize(1..32);
        let timeout_us = g.u64(50..5_000);
        let n = g.usize(1..120);
        let stats = Arc::new(ServerStats::new());
        let b: DynamicBatcher<u64, u64> = DynamicBatcher::new(
            max_batch,
            Duration::from_micros(timeout_us),
            Arc::clone(&stats),
            |items| items.into_iter().map(|&x| Ok(x * 2)).collect(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..n as u64).map(|i| (i, b.submit(i).unwrap())).collect();
        for (i, rx) in rxs {
            let got = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("reply within deadline")
                .expect("flush ok");
            assert_eq!(got, i * 2, "request {i} got wrong reply");
        }
        let snap = stats.snapshot();
        assert_eq!(snap.batched_requests, n as u64, "requests conserved");
        assert!(snap.batches_flushed >= n.div_ceil(max_batch) as u64);
        b.shutdown();
    });
}

#[test]
fn batcher_never_exceeds_max_batch() {
    prop("batch size bound", 8, |g| {
        let max_batch = g.usize(1..16);
        let n = g.usize(1..100);
        let stats = Arc::new(ServerStats::new());
        let seen = Arc::new(std::sync::Mutex::new(Vec::<usize>::new()));
        let seen2 = Arc::clone(&seen);
        let b: DynamicBatcher<u64, u64> = DynamicBatcher::new(
            max_batch,
            Duration::from_micros(200),
            stats,
            move |items| {
                seen2.lock().unwrap().push(items.len());
                items.into_iter().map(|&x| Ok(x)).collect()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..n as u64).map(|i| b.submit(i).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        b.shutdown();
        for &size in seen.lock().unwrap().iter() {
            assert!(size <= max_batch, "batch {size} > max {max_batch}");
            assert!(size >= 1);
        }
    });
}

#[test]
fn routing_returns_requested_backend_with_consistent_sums() {
    let (m, cm, d) = models();
    let cfg = ServeConfig { workers: 3, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m.clone(), cm.clone(), false).unwrap();
    prop("routing consistency", 40, |g| {
        let hw = [
            Backend::SyncMulticlass,
            Backend::AsyncBdMulticlass,
            Backend::ProposedMulticlass,
            Backend::SyncCotm,
            Backend::AsyncBdCotm,
            Backend::ProposedCotm,
        ];
        let b = *g.pick(&hw);
        let i = g.usize(0..d.len());
        let r = srv
            .infer(InferRequest { features: d.features[i].clone(), backend: b })
            .unwrap();
        assert_eq!(r.backend, b);
        let want = match b {
            Backend::SyncCotm | Backend::AsyncBdCotm | Backend::ProposedCotm => {
                tsetlin_td::tm::infer::cotm_class_sums(&cm, &d.features[i])
            }
            _ => tsetlin_td::tm::infer::multiclass_class_sums(&m, &d.features[i]),
        };
        assert_eq!(r.class_sums, want, "backend {b:?} sample {i}");
    });
    srv.shutdown();
}

#[test]
fn counters_conserve_under_backpressure() {
    prop("counter conservation", 6, |g| {
        let (m, cm, d) = models();
        let queue_depth = g.usize(8..64);
        let cfg = ServeConfig {
            workers: g.usize(1..4),
            queue_depth,
            max_batch: 8,
            ..ServeConfig::default()
        };
        let srv = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
        let n = g.usize(50..250);
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for i in 0..n {
            match srv.submit(InferRequest {
                features: d.features[i % d.len()].clone(),
                backend: Backend::ProposedCotm,
            }) {
                Ok(rx) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        let mut completed = 0u64;
        for rx in accepted {
            if rx
                .recv_timeout(Duration::from_secs(60))
                .map(|r| r.is_ok())
                .unwrap_or(false)
            {
                completed += 1;
            }
        }
        let snap = srv.stats().clone();
        // Conservation: submitted = completed + failed; rejected tracked
        // separately; nothing lost.
        assert_eq!(snap.submitted, completed + snap.failed);
        assert_eq!(snap.rejected, rejected);
        assert_eq!(snap.submitted + snap.rejected, n as u64);
        srv.shutdown();
    });
}

#[test]
fn state_repeat_requests_are_deterministic_per_backend() {
    // Architecture instances carry per-worker activity state (prev
    // vectors); predictions must still be pure functions of the input.
    let (m, cm, d) = models();
    let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
    for backend in [Backend::ProposedMulticlass, Backend::ProposedCotm] {
        let mut first: Option<(usize, Vec<i32>)> = None;
        for _ in 0..6 {
            let r = srv
                .infer(InferRequest { features: d.features[17].clone(), backend })
                .unwrap();
            match &first {
                None => first = Some((r.predicted, r.class_sums.clone())),
                Some((p, sums)) => {
                    assert_eq!(r.predicted, *p, "{backend:?}");
                    assert_eq!(&r.class_sums, sums, "{backend:?}");
                }
            }
        }
    }
    srv.shutdown();
}
