//! Property-based tests on the coordinator invariants (DESIGN.md item
//! (c)): routing (responses come from the requested backend and carry
//! the right semantics), batching (no request lost, dropped or
//! duplicated across arbitrary batch/timeout configurations), and state
//! (counters are conserved under concurrent mixed load + backpressure).

use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::Duration;

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::batcher::{DynamicBatcher, Pending};
use tsetlin_td::coordinator::shard::{hash_features, hash_key, HashRing, DEFAULT_VNODES};
use tsetlin_td::coordinator::stats::ServerStats;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest, ShardedCoordinator};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::util::lock_unpoisoned;
use tsetlin_td::tm::{cotm_train::train_cotm, data, train::train_multiclass, TmParams};

fn models() -> (tsetlin_td::tm::MultiClassTmModel, tsetlin_td::tm::CoTmModel, data::Dataset) {
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m = train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
    let cm = train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
    (m, cm, d)
}

#[test]
fn batcher_conserves_requests_under_random_configs() {
    prop("batcher conservation", 12, |g| {
        let max_batch = g.usize(1..32);
        let timeout_us = g.u64(50..5_000);
        let n = g.usize(1..120);
        let stats = Arc::new(ServerStats::new());
        let b: DynamicBatcher<u64, u64> = DynamicBatcher::new(
            max_batch,
            Duration::from_micros(timeout_us),
            Arc::clone(&stats),
            Arc::new(AtomicU64::new(u64::MAX / 2)),
            |batch: &[Pending<u64, u64>]| batch.iter().map(|p| Ok(p.item * 2)).collect(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..n as u64).map(|i| (i, b.submit(i).unwrap())).collect();
        for (i, rx) in rxs {
            let got = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("reply within deadline")
                .expect("flush ok");
            assert_eq!(got, i * 2, "request {i} got wrong reply");
        }
        let snap = stats.snapshot();
        assert_eq!(snap.batched_requests, n as u64, "requests conserved");
        assert!(snap.batches_flushed >= n.div_ceil(max_batch) as u64);
        b.shutdown();
    });
}

#[test]
fn batcher_never_exceeds_max_batch() {
    prop("batch size bound", 8, |g| {
        let max_batch = g.usize(1..16);
        let n = g.usize(1..100);
        let stats = Arc::new(ServerStats::new());
        let seen = Arc::new(std::sync::Mutex::new(Vec::<usize>::new()));
        let seen2 = Arc::clone(&seen);
        let b: DynamicBatcher<u64, u64> = DynamicBatcher::new(
            max_batch,
            Duration::from_micros(200),
            stats,
            Arc::new(AtomicU64::new(u64::MAX / 2)),
            move |batch: &[Pending<u64, u64>]| {
                lock_unpoisoned(&seen2).push(batch.len());
                batch.iter().map(|p| Ok(p.item)).collect()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..n as u64).map(|i| b.submit(i).unwrap()).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        b.shutdown();
        for &size in lock_unpoisoned(&seen).iter() {
            assert!(size <= max_batch, "batch {size} > max {max_batch}");
            assert!(size >= 1);
        }
    });
}

#[test]
fn routing_returns_requested_backend_with_consistent_sums() {
    let (m, cm, d) = models();
    let cfg = ServeConfig { workers: 3, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m.clone(), cm.clone(), false).unwrap();
    prop("routing consistency", 40, |g| {
        let hw = [
            Backend::SyncMulticlass,
            Backend::AsyncBdMulticlass,
            Backend::ProposedMulticlass,
            Backend::SyncCotm,
            Backend::AsyncBdCotm,
            Backend::ProposedCotm,
        ];
        let b = *g.pick(&hw);
        let i = g.usize(0..d.len());
        let r = srv
            .infer(InferRequest { features: d.features[i].clone(), backend: b })
            .unwrap();
        assert_eq!(r.backend, b);
        let want = match b {
            Backend::SyncCotm | Backend::AsyncBdCotm | Backend::ProposedCotm => {
                tsetlin_td::tm::infer::cotm_class_sums(&cm, &d.features[i])
            }
            _ => tsetlin_td::tm::infer::multiclass_class_sums(&m, &d.features[i]),
        };
        assert_eq!(r.class_sums, want, "backend {b:?} sample {i}");
    });
    srv.shutdown();
}

#[test]
fn counters_conserve_under_backpressure() {
    prop("counter conservation", 6, |g| {
        let (m, cm, d) = models();
        let queue_depth = g.usize(8..64);
        let cfg = ServeConfig {
            workers: g.usize(1..4),
            queue_depth,
            max_batch: 8,
            ..ServeConfig::default()
        };
        let srv = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
        let n = g.usize(50..250);
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        for i in 0..n {
            match srv.submit(InferRequest {
                features: d.features[i % d.len()].clone(),
                backend: Backend::ProposedCotm,
            }) {
                Ok(rx) => accepted.push(rx),
                Err(_) => rejected += 1,
            }
        }
        let mut completed = 0u64;
        for rx in accepted {
            if rx
                .recv_timeout(Duration::from_secs(60))
                .map(|r| r.is_ok())
                .unwrap_or(false)
            {
                completed += 1;
            }
        }
        let snap = srv.stats().clone();
        // Conservation: submitted = completed + failed; rejected tracked
        // separately; nothing lost.
        assert_eq!(snap.submitted, completed + snap.failed);
        assert_eq!(snap.rejected, rejected);
        assert_eq!(snap.submitted + snap.rejected, n as u64);
        srv.shutdown();
    });
}

#[test]
fn shard_routing_is_deterministic_and_balanced() {
    prop("hash ring routing", 6, |g| {
        let shards = g.usize(2..9);
        let ring = HashRing::new(shards, DEFAULT_VNODES).unwrap();
        let rebuilt = HashRing::new(shards, DEFAULT_VNODES).unwrap();
        let mut counts = vec![0usize; shards];
        for _ in 0..4000 {
            let key = g.u64(0..u64::MAX);
            let s = ring.shard_for_hash(hash_key(key));
            assert_eq!(s, rebuilt.shard_for_hash(hash_key(key)), "rebuild-deterministic");
            assert!(s < shards, "shard {s} out of range");
            counts[s] += 1;
        }
        // Consistent hashing is only statistically fair; with 128
        // vnodes/shard the arcs stay within a loose envelope of fair
        // share (measured <= ~1.25x across 2..=8 shards).
        let fair = 4000.0 / shards as f64;
        for (s, &n) in counts.iter().enumerate() {
            assert!(
                (n as f64) > 0.3 * fair && (n as f64) < 2.0 * fair,
                "shard {s} got {n} of {shards}-way split (fair {fair:.0})"
            );
        }
        // Feature-keyed routing is a pure function of the bits.
        let x = g.bools(g.usize(1..64));
        assert_eq!(
            ring.shard_for_hash(hash_features(&x)),
            ring.shard_for_hash(hash_features(&x))
        );
    });
}

#[test]
fn sharded_backpressure_accounts_per_shard() {
    // Flood a single shard via an explicit key: only that shard may
    // reject, and the idle shard's counters stay untouched.
    let (m, cm, d) = models();
    let cfg = ServeConfig {
        shards: 2,
        workers: 1,
        queue_depth: 16,
        max_batch: 16,
        ..ServeConfig::default()
    };
    let srv = ShardedCoordinator::new(&cfg, m, cm, false).unwrap();
    let key = 7u64;
    let target = srv.shard_for_key(key);
    let mut accepted = Vec::new();
    let mut rejected = 0u64;
    for i in 0..300 {
        match srv.submit_keyed(
            key,
            InferRequest {
                features: d.features[i % d.len()].clone(),
                backend: Backend::ProposedCotm,
            },
        ) {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in accepted {
        let _ = rx.recv_timeout(Duration::from_secs(60));
    }
    assert!(rejected > 0, "expected backpressure on the flooded shard");
    let per_shard = srv.shard_stats();
    assert_eq!(per_shard[target].rejected, rejected);
    let idle = 1 - target;
    assert_eq!(per_shard[idle].submitted, 0, "idle shard saw no traffic");
    assert_eq!(per_shard[idle].rejected, 0);
    // Aggregate view sums the shards.
    let agg = srv.stats();
    assert_eq!(agg.rejected, rejected);
    assert_eq!(agg.submitted, per_shard[target].submitted);
    srv.shutdown();
}

#[test]
fn state_repeat_requests_are_deterministic_per_backend() {
    // Architecture instances carry per-worker activity state (prev
    // vectors); predictions must still be pure functions of the input.
    let (m, cm, d) = models();
    let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
    let srv = CoordinatorServer::new(&cfg, m, cm, false).unwrap();
    for backend in [Backend::ProposedMulticlass, Backend::ProposedCotm] {
        let mut first: Option<(usize, Vec<i32>)> = None;
        for _ in 0..6 {
            let r = srv
                .infer(InferRequest { features: d.features[17].clone(), backend })
                .unwrap();
            match &first {
                None => first = Some((r.predicted, r.class_sums.clone())),
                Some((p, sums)) => {
                    assert_eq!(r.predicted, *p, "{backend:?}");
                    assert_eq!(&r.class_sums, sums, "{backend:?}");
                }
            }
        }
    }
    srv.shutdown();
}
