//! Runtime-dispatch conformance for the SIMD evaluation tier
//! (`tm::simd`): the lane width — scalar single-word, portable
//! 4×`u64`-unrolled, AVX2, AVX-512 when detected — is a *speed*
//! decision only. Every property here forces each available level
//! through the same models and inputs and demands bit-identical class
//! sums and argmax, with the portable path as the pinned reference and
//! the scalar reference `tm::infer` as the ground truth.
//!
//! This suite also runs under `--no-default-features` (vector paths
//! compiled out): the available set then degenerates to
//! scalar + portable and every property still holds, which is what
//! keeps the portable reference self-sufficient.

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, InferRequest, ShardedCoordinator};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::bitpack::{eval_words_train_with, pack_literals};
use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums, predict_argmax};
use tsetlin_td::tm::model::make_literals;
use tsetlin_td::tm::simd::{SimdChoice, SimdLevel, WordLanes};
use tsetlin_td::tm::{
    data, BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    MultiClassTmModel, TmParams,
};

/// Word-boundary feature widths (shared with bitparallel_equivalence).
const BOUNDARY_WIDTHS: [usize; 10] = [1, 5, 31, 32, 33, 63, 64, 65, 97, 130];

fn draw_features(g: &mut Gen) -> usize {
    if g.chance(0.6) {
        *g.pick(&BOUNDARY_WIDTHS)
    } else {
        g.usize(1..200)
    }
}

fn draw_density(g: &mut Gen) -> f64 {
    if g.chance(0.15) {
        0.0
    } else {
        0.02 + 0.4 * g.f64_unit()
    }
}

fn random_multiclass(g: &mut Gen, f: usize, c: usize, k: usize) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = MultiClassTmModel::zeroed(p);
    let density = draw_density(g);
    for class in &mut m.clauses {
        for clause in class.iter_mut() {
            *clause = ClauseMask {
                include: (0..2 * f).map(|_| g.chance(density)).collect(),
            };
        }
    }
    m
}

fn random_cotm(g: &mut Gen, f: usize, c: usize, k: usize) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = CoTmModel::zeroed(p.clone());
    let density = draw_density(g);
    for clause in &mut m.clauses {
        *clause = ClauseMask {
            include: (0..2 * f).map(|_| g.chance(density)).collect(),
        };
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = g.i64(-(p.max_weight as i64)..p.max_weight as i64 + 1) as i32;
        }
    }
    m
}

#[test]
fn dispatch_never_offers_an_unavailable_level() {
    let avail = SimdLevel::available();
    assert!(avail.contains(&SimdLevel::Scalar));
    assert!(avail.contains(&SimdLevel::Portable));
    for level in &avail {
        assert!(level.is_available());
        assert!(WordLanes::new(*level).is_ok());
    }
    assert!(avail.contains(&SimdLevel::detect_best()));
    // Forcing an unavailable level errors cleanly instead of faulting.
    for level in SimdLevel::ALL {
        if !level.is_available() {
            let err = SimdChoice::Forced(level).resolve().unwrap_err();
            assert!(err.to_string().contains("not available"), "{err}");
        }
    }
}

#[test]
fn all_levels_bit_identical_class_sums_and_argmax_multiclass() {
    // The satellite property: scalar, portable(unrolled), AVX2 and
    // AVX-512 (when detected) produce bit-identical class sums and
    // argmax on random models, across word-boundary widths and batch
    // sizes crossing the 64-sample block and the 8-block tile.
    prop("simd dispatch multiclass", 60, |g| {
        let f = draw_features(g);
        let c = 2 * g.usize(1..6);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 130, 513, 600]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let portable = BitParallelMulticlass::from_model(&m)
            .unwrap()
            .with_lanes(WordLanes::portable());
        let want = portable.infer_batch(&rows);
        // Ground truth on a sample of rows (full scan is O(n·c·f)).
        for (s, (sums, pred)) in want.iter().enumerate().take(8) {
            let truth = multiclass_class_sums(&m, &rows[s]);
            assert_eq!(sums, &truth, "portable vs scalar reference, sample {s}");
            assert_eq!(*pred, predict_argmax(&truth));
        }
        for level in SimdLevel::available() {
            let e = BitParallelMulticlass::from_model(&m)
                .unwrap()
                .with_lanes(WordLanes::new(level).unwrap());
            assert_eq!(e.infer_batch(&rows), want, "f={f} n={n} level {}", level.name());
            for x in rows.iter().take(4) {
                assert_eq!(
                    e.class_sums(x),
                    portable.class_sums(x),
                    "single-sample f={f} level {}",
                    level.name()
                );
            }
        }
    });
}

#[test]
fn all_levels_bit_identical_class_sums_and_argmax_cotm() {
    prop("simd dispatch cotm", 60, |g| {
        let f = draw_features(g);
        let c = g.usize(1..12);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let n = *g.pick(&[1usize, 2, 63, 64, 65, 130, 600]);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let portable =
            BitParallelCotm::from_model(&m).unwrap().with_lanes(WordLanes::portable());
        let want = portable.infer_batch(&rows);
        for (s, (sums, _)) in want.iter().enumerate().take(8) {
            assert_eq!(
                sums,
                &cotm_class_sums(&m, &rows[s]),
                "portable vs scalar reference, sample {s}"
            );
        }
        for level in SimdLevel::available() {
            let e = BitParallelCotm::from_model(&m)
                .unwrap()
                .with_lanes(WordLanes::new(level).unwrap());
            assert_eq!(e.infer_batch(&rows), want, "f={f} n={n} level {}", level.name());
        }
    });
}

#[test]
fn forced_portable_vs_detected_parity_on_trained_iris() {
    // The forced-portable-vs-detected parity bar: whatever `auto`
    // resolves to on this host must reproduce the portable engine's
    // output on real trained models, through the single-sample, batched
    // and sharded paths.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m =
        tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 60, 2).unwrap();
    let cm =
        tsetlin_td::tm::cotm_train::train_cotm(TmParams::iris_paper(), &tr, 150, 3).unwrap();

    let portable_mc =
        BitParallelMulticlass::from_model(&m).unwrap().with_lanes(WordLanes::portable());
    let detected_mc =
        BitParallelMulticlass::from_model(&m).unwrap().with_lanes(WordLanes::detect());
    let portable_co =
        BitParallelCotm::from_model(&cm).unwrap().with_lanes(WordLanes::portable());
    let detected_co =
        BitParallelCotm::from_model(&cm).unwrap().with_lanes(WordLanes::detect());

    let want_mc = portable_mc.infer_batch(&d.features);
    let want_co = portable_co.infer_batch(&d.features);
    assert_eq!(detected_mc.infer_batch(&d.features), want_mc);
    assert_eq!(detected_co.infer_batch(&d.features), want_co);
    assert_eq!(detected_mc.infer_batch_sharded(&d.features, 4), want_mc);
    assert_eq!(detected_co.infer_batch_sharded(&d.features, 4), want_co);
    for (i, x) in d.features.iter().enumerate() {
        assert_eq!(detected_mc.class_sums(x), portable_mc.class_sums(x), "sample {i}");
        assert_eq!(detected_co.class_sums(x), portable_co.class_sums(x), "sample {i}");
        // And both equal the scalar ground truth.
        assert_eq!(want_mc[i].0, multiclass_class_sums(&m, x), "sample {i}");
        assert_eq!(want_co[i].0, cotm_class_sums(&cm, x), "sample {i}");
    }
}

#[test]
fn trainer_predicate_is_dispatch_invariant() {
    // eval_words_train (the trainer engine's firing predicate) must
    // answer identically at every lane width — this is what keeps the
    // packed-trainer bit-identity contract safe under dispatch.
    prop("training predicate dispatch", 120, |g| {
        let f = g.usize(1..150);
        let density = draw_density(g);
        let include_bits: Vec<bool> = (0..2 * f).map(|_| g.chance(density)).collect();
        let include = tsetlin_td::tm::bitpack::pack_bools(&include_bits);
        let x = g.bools(f);
        let words = pack_literals(&x);
        let lits = make_literals(&x);
        // Ground truth: the per-literal training walk (empty fires).
        let want = include_bits.iter().zip(&lits).all(|(&inc, &lit)| !inc || lit);
        for level in SimdLevel::available() {
            assert_eq!(
                eval_words_train_with(&include, &words, WordLanes::new(level).unwrap()),
                want,
                "f={f} level {}",
                level.name()
            );
        }
    });
}

#[test]
fn sharded_front_door_is_simd_invariant() {
    // The whole serving stack — batcher, shards, ring — with the SIMD
    // level forced through ServeConfig: responses must be bit-exact
    // against the scalar reference at every available level, and
    // identical across levels.
    let d = data::iris().unwrap();
    let (tr, _) = d.split(0.8, 42);
    let m =
        tsetlin_td::tm::train::train_multiclass(TmParams::iris_paper(), &tr, 20, 2).unwrap();
    let cm =
        tsetlin_td::tm::cotm_train::train_cotm(TmParams::iris_paper(), &tr, 20, 3).unwrap();
    let samples: Vec<usize> = vec![0, 33, 77, 149];
    let mut by_level: Vec<Vec<Vec<i32>>> = Vec::new();
    for level in SimdLevel::available() {
        let cfg = ServeConfig {
            shards: 2,
            workers: 1,
            simd: SimdChoice::Forced(level),
            ..ServeConfig::default()
        };
        let srv = ShardedCoordinator::new(&cfg, m.clone(), cm.clone(), false).unwrap();
        assert_eq!(srv.simd_lanes().level(), level);
        let mut sums = Vec::new();
        for &i in &samples {
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelMulticlass,
                })
                .unwrap();
            assert_eq!(
                r.class_sums,
                multiclass_class_sums(&m, &d.features[i]),
                "sample {i} level {}",
                level.name()
            );
            sums.push(r.class_sums);
            let r = srv
                .infer(InferRequest {
                    features: d.features[i].clone(),
                    backend: Backend::BitParallelCotm,
                })
                .unwrap();
            assert_eq!(
                r.class_sums,
                cotm_class_sums(&cm, &d.features[i]),
                "sample {i} level {}",
                level.name()
            );
            sums.push(r.class_sums);
        }
        by_level.push(sums);
        srv.shutdown();
    }
    for w in by_level.windows(2) {
        assert_eq!(w[0], w[1], "levels must be interchangeable end to end");
    }
}
