//! The cross-engine differential conformance matrix.
//!
//! Four engine families now serve the same TM semantics: the scalar
//! reference (`tm::infer`), the bit-parallel packed engines (at every
//! available SIMD lane width), the event-driven inverted-index engines,
//! and the compressed include-list engines (ETHEREAL tier). Instead of
//! per-PR pairwise suites, this harness instantiates **every** engine
//! family × available SIMD level on the same random models and demands
//! bit-identical class sums and argmax across the whole matrix, with
//! the scalar reference as ground truth.
//!
//! The sweep is deliberately adversarial: word-boundary feature widths
//! (31/32/33/63/64/65), an all-exclude clause and an all-include
//! (contradictory — one literal pair is always unsatisfied) clause
//! pinned into every model, and batch sizes crossing both the
//! 64-sample block and the 8-block (512-sample) tile of the packed
//! layout.
//!
//! The three-way `auto-*` property rides here too: any
//! (indexed_density_threshold, compressed_density_threshold) pair —
//! including the 0.0/1.0 edges and inverted pairs — may change which
//! engine serves, never what it answers.

use tsetlin_td::config::ServeConfig;
use tsetlin_td::coordinator::{Backend, CoordinatorServer, InferRequest};
use tsetlin_td::testutil::{prop, Gen};
use tsetlin_td::tm::fast_infer::BatchResult;
use tsetlin_td::tm::infer::{cotm_class_sums, multiclass_class_sums, predict_argmax};
use tsetlin_td::tm::simd::{SimdLevel, WordLanes};
use tsetlin_td::tm::{
    BatchEngine, BitParallelCotm, BitParallelMulticlass, ClauseMask, CoTmModel,
    CompileMode, CompiledCotm, CompiledMulticlass, CompressedCotm, CompressedMulticlass,
    IndexedCotm, IndexedMulticlass, ModelCompiler, MultiClassTmModel, TmParams,
};

/// Word-boundary feature widths: one below, at, and above the half-word
/// and full-word edges of the 64-bit packed literal layout (2F bits).
const BOUNDARY_WIDTHS: [usize; 6] = [31, 32, 33, 63, 64, 65];

/// Batch sizes crossing the 64-sample block (63/64/65) and the 8-block
/// 512-sample tile (513/520) of the bit-sliced batch layout.
const BATCH_SIZES: [usize; 7] = [1, 63, 64, 65, 130, 513, 520];

/// A clause mask for slot `j`: slot 0 is pinned all-exclude (never
/// fires), slot 1 all-include (contradictory: includes both of every
/// literal pair, so it never fires either — but only after walking),
/// the rest random at the drawn density.
fn draw_mask(g: &mut Gen, j: usize, f: usize, density: f64) -> ClauseMask {
    let include = match j {
        0 => vec![false; 2 * f],
        1 => vec![true; 2 * f],
        _ => (0..2 * f).map(|_| g.chance(density)).collect(),
    };
    ClauseMask { include }
}

fn random_multiclass(g: &mut Gen, f: usize, c: usize, k: usize) -> MultiClassTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = MultiClassTmModel::zeroed(p);
    let density = 0.05 + 0.4 * g.f64_unit();
    for class in &mut m.clauses {
        for (j, clause) in class.iter_mut().enumerate() {
            *clause = draw_mask(g, j, f, density);
        }
    }
    m
}

fn random_cotm(g: &mut Gen, f: usize, c: usize, k: usize) -> CoTmModel {
    let p = TmParams { features: f, clauses: c, classes: k, ..TmParams::iris_paper() };
    let mut m = CoTmModel::zeroed(p.clone());
    let density = 0.05 + 0.4 * g.f64_unit();
    for (j, clause) in m.clauses.iter_mut().enumerate() {
        *clause = draw_mask(g, j, f, density);
    }
    for row in &mut m.weights {
        for w in row.iter_mut() {
            *w = g.i64(-(p.max_weight as i64)..p.max_weight as i64 + 1) as i32;
        }
    }
    m
}

/// Every multiclass engine instance in the matrix, as named batch
/// evaluators: bit-parallel at each available SIMD level, indexed, and
/// compressed. (`BatchEngine` is not object-safe — generic
/// `infer_batch` — so the matrix is a list of closures, each owning
/// its engine.)
type MatrixEngine = (String, Box<dyn Fn(&[Vec<bool>]) -> Vec<BatchResult>>);

fn multiclass_matrix(m: &MultiClassTmModel) -> Vec<MatrixEngine> {
    let mut v: Vec<MatrixEngine> = Vec::new();
    for level in SimdLevel::available() {
        let e = BitParallelMulticlass::from_model(m)
            .unwrap()
            .with_lanes(WordLanes::new(level).unwrap());
        v.push((
            format!("bitpar/{}", level.name()),
            Box::new(move |rows: &[Vec<bool>]| e.infer_batch(rows)),
        ));
    }
    let ix = IndexedMulticlass::from_model(m).unwrap();
    v.push(("indexed".into(), Box::new(move |rows: &[Vec<bool>]| ix.infer_batch(rows))));
    let cp = CompressedMulticlass::from_model(m).unwrap();
    v.push(("compressed".into(), Box::new(move |rows: &[Vec<bool>]| cp.infer_batch(rows))));
    v
}

fn cotm_matrix(m: &CoTmModel) -> Vec<MatrixEngine> {
    let mut v: Vec<MatrixEngine> = Vec::new();
    for level in SimdLevel::available() {
        let e = BitParallelCotm::from_model(m)
            .unwrap()
            .with_lanes(WordLanes::new(level).unwrap());
        v.push((
            format!("bitpar/{}", level.name()),
            Box::new(move |rows: &[Vec<bool>]| e.infer_batch(rows)),
        ));
    }
    let ix = IndexedCotm::from_model(m).unwrap();
    v.push(("indexed".into(), Box::new(move |rows: &[Vec<bool>]| ix.infer_batch(rows))));
    let cp = CompressedCotm::from_model(m).unwrap();
    v.push(("compressed".into(), Box::new(move |rows: &[Vec<bool>]| cp.infer_batch(rows))));
    v
}

#[test]
fn matrix_covers_every_engine_family_and_level() {
    // The matrix must actually contain what the harness claims:
    // one bit-parallel instance per available SIMD level (scalar and
    // portable at minimum), plus the indexed and compressed families.
    let m = random_multiclass(&mut Gen::new(7), 32, 4, 3);
    let names: Vec<String> =
        multiclass_matrix(&m).into_iter().map(|(name, _)| name).collect();
    assert!(names.len() >= 4, "{names:?}");
    assert!(names.contains(&"bitpar/scalar".to_string()), "{names:?}");
    assert!(names.contains(&"bitpar/portable".to_string()), "{names:?}");
    assert!(names.contains(&"indexed".to_string()), "{names:?}");
    assert!(names.contains(&"compressed".to_string()), "{names:?}");
    assert_eq!(names.len(), SimdLevel::available().len() + 2);
}

#[test]
fn multiclass_matrix_is_bit_identical_on_boundary_widths() {
    prop("engine matrix multiclass", 18, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let c = 2 * g.usize(1..4); // >= 2 clauses: slots 0 and 1 exist
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let n = *g.pick(&BATCH_SIZES);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        // Ground truth: the scalar reference, row by row.
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = multiclass_class_sums(&m, x);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect();
        for (name, eval) in multiclass_matrix(&m) {
            assert_eq!(eval(&rows), want, "f={f} c={c} k={k} n={n} engine {name}");
        }
    });
}

#[test]
fn cotm_matrix_is_bit_identical_on_boundary_widths() {
    prop("engine matrix cotm", 18, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let c = g.usize(2..9);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let n = *g.pick(&BATCH_SIZES);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = cotm_class_sums(&m, x);
                let pred = predict_argmax(&sums);
                (sums, pred)
            })
            .collect();
        for (name, eval) in cotm_matrix(&m) {
            assert_eq!(eval(&rows), want, "f={f} c={c} k={k} n={n} engine {name}");
        }
    });
}

#[test]
fn matrix_agrees_on_single_sample_and_sharded_paths() {
    // The trait's three entry points — class_sums, infer_batch,
    // infer_batch_sharded — must agree within and across families on a
    // tile-crossing batch. (The batched path is already matrixed above;
    // this pins the other two on concrete engines.)
    prop("engine matrix entry points", 6, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let m = random_multiclass(g, f, 4, 3);
        let rows: Vec<Vec<bool>> = (0..520).map(|_| g.bools(f)).collect();
        let bp = BitParallelMulticlass::from_model(&m).unwrap();
        let ix = IndexedMulticlass::from_model(&m).unwrap();
        let cp = CompressedMulticlass::from_model(&m).unwrap();
        let want = bp.infer_batch(&rows);
        assert_eq!(bp.infer_batch_sharded(&rows, 4), want, "f={f} bitpar sharded");
        assert_eq!(ix.infer_batch_sharded(&rows, 4), want, "f={f} indexed sharded");
        assert_eq!(cp.infer_batch_sharded(&rows, 4), want, "f={f} compressed sharded");
        for (s, x) in rows.iter().enumerate().take(8) {
            assert_eq!(bp.class_sums(x), want[s].0, "f={f} sample {s} bitpar");
            assert_eq!(ix.class_sums(x), want[s].0, "f={f} sample {s} indexed");
            assert_eq!(cp.class_sums(x), want[s].0, "f={f} sample {s} compressed");
            assert_eq!(cp.predict(x), want[s].1, "f={f} sample {s}");
        }
    });
}

#[test]
fn edge_clauses_are_matrix_invariant() {
    // All-exclude and all-include models in isolation: every engine
    // family must answer all-zero sums (empty clauses never fire;
    // all-include clauses are contradictory) at every boundary width.
    for &f in &BOUNDARY_WIDTHS {
        let p = TmParams { features: f, clauses: 2, classes: 2, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        for class in &mut m.clauses {
            class[1] = ClauseMask { include: vec![true; 2 * f] };
        }
        let rows: Vec<Vec<bool>> = (0..65usize)
            .map(|s| (0..f).map(|i| (s + i) % 3 == 0).collect())
            .collect();
        let want: Vec<BatchResult> = rows.iter().map(|_| (vec![0, 0], 0)).collect();
        for (name, eval) in multiclass_matrix(&m) {
            assert_eq!(eval(&rows), want, "f={f} engine {name}");
        }
        // And the reference itself agrees that nothing fires.
        assert_eq!(multiclass_class_sums(&m, &rows[0]), vec![0, 0], "f={f}");
    }
}

#[test]
fn auto_threshold_pairs_never_change_served_outputs() {
    // The three-way auto selection property: every
    // (indexed_density_threshold, compressed_density_threshold) pair —
    // edges, inverted pairs, random interior points — picks some
    // native engine, and the served sums are identical across all of
    // them and equal to the scalar reference.
    prop("auto three-way invariance", 3, |g| {
        let f = g.usize(6..20);
        let m = random_multiclass(g, f, 4, 3);
        let cm = random_cotm(g, f, 4, 3);
        let samples: Vec<Vec<bool>> = (0..4).map(|_| g.bools(f)).collect();
        let pairs = [
            (0.0, 0.0),
            (0.0, 1.0),
            (1.0, 0.0),
            (1.0, 1.0),
            (g.f64_unit(), g.f64_unit()),
        ];
        let mut by_pair: Vec<Vec<Vec<i32>>> = Vec::new();
        for &(it, ct) in &pairs {
            let cfg = ServeConfig {
                workers: 1,
                indexed_density_threshold: it,
                compressed_density_threshold: ct,
                ..ServeConfig::default()
            };
            let srv = CoordinatorServer::new(&cfg, m.clone(), cm.clone(), false).unwrap();
            let (auto_mc, auto_co) = srv.auto_backends();
            // The alias always resolves to a concrete native engine.
            assert!(auto_mc.is_native_batched(), "({it}, {ct}) -> {auto_mc:?}");
            assert!(auto_co.is_native_batched(), "({it}, {ct}) -> {auto_co:?}");
            let mut sums = Vec::new();
            for x in &samples {
                let r = srv
                    .infer(InferRequest {
                        features: x.clone(),
                        backend: Backend::AutoMulticlass,
                    })
                    .unwrap();
                assert_eq!(r.backend, auto_mc, "({it}, {ct})");
                assert_eq!(
                    r.class_sums,
                    multiclass_class_sums(&m, x),
                    "({it}, {ct}) multiclass"
                );
                sums.push(r.class_sums);
                let r = srv
                    .infer(InferRequest { features: x.clone(), backend: Backend::AutoCotm })
                    .unwrap();
                assert_eq!(r.backend, auto_co, "({it}, {ct})");
                assert_eq!(r.class_sums, cotm_class_sums(&cm, x), "({it}, {ct}) cotm");
                sums.push(r.class_sums);
            }
            by_pair.push(sums);
            srv.shutdown();
        }
        for w in by_pair.windows(2) {
            assert_eq!(w[0], w[1], "threshold pairs must be interchangeable");
        }
    });
}

/// The compile-pass counterpart of the matrices above: every engine
/// family × available SIMD level built from a shared compiled artifact
/// instead of the raw model.
fn multiclass_matrix_compiled(compiled: &CompiledMulticlass) -> Vec<MatrixEngine> {
    let mut v: Vec<MatrixEngine> = Vec::new();
    for level in SimdLevel::available() {
        let e = BitParallelMulticlass::from_compiled(compiled)
            .unwrap()
            .with_lanes(WordLanes::new(level).unwrap());
        v.push((
            format!("bitpar/{}", level.name()),
            Box::new(move |rows: &[Vec<bool>]| e.infer_batch(rows)),
        ));
    }
    let ix = IndexedMulticlass::from_compiled(compiled).unwrap();
    v.push(("indexed".into(), Box::new(move |rows: &[Vec<bool>]| ix.infer_batch(rows))));
    let cp = CompressedMulticlass::from_compiled(compiled).unwrap();
    v.push(("compressed".into(), Box::new(move |rows: &[Vec<bool>]| cp.infer_batch(rows))));
    v
}

fn cotm_matrix_compiled(compiled: &CompiledCotm) -> Vec<MatrixEngine> {
    let mut v: Vec<MatrixEngine> = Vec::new();
    for level in SimdLevel::available() {
        let e = BitParallelCotm::from_compiled(compiled)
            .unwrap()
            .with_lanes(WordLanes::new(level).unwrap());
        v.push((
            format!("bitpar/{}", level.name()),
            Box::new(move |rows: &[Vec<bool>]| e.infer_batch(rows)),
        ));
    }
    let ix = IndexedCotm::from_compiled(compiled).unwrap();
    v.push(("indexed".into(), Box::new(move |rows: &[Vec<bool>]| ix.infer_batch(rows))));
    let cp = CompressedCotm::from_compiled(compiled).unwrap();
    v.push(("compressed".into(), Box::new(move |rows: &[Vec<bool>]| cp.infer_batch(rows))));
    v
}

/// One compiler per compile mode; "full" gets a drawn synthetic
/// calibration batch so the reorder path actually runs.
fn compilers(g: &mut Gen, f: usize) -> Vec<(&'static str, ModelCompiler)> {
    vec![
        ("off", ModelCompiler::new(CompileMode::Off)),
        ("prune", ModelCompiler::new(CompileMode::Prune)),
        (
            "full",
            ModelCompiler::new(CompileMode::Full).with_synthetic_calibration(
                f,
                g.usize(1..64),
                g.u64(0..u64::MAX),
            ),
        ),
    ]
}

#[test]
fn compiled_multiclass_matrix_is_bit_identical_on_boundary_widths() {
    // The headline compile-pass bar: compiled vs uncompiled serving is
    // bit-identical (sums and argmax) across every engine family ×
    // SIMD level, at word-boundary widths, on tile-crossing batches,
    // in every compile mode. The drawn models always carry the pinned
    // all-exclude (slot 0) and contradictory (slot 1) clauses, so
    // pruning really removes clauses in every case.
    prop("compiled engine matrix multiclass", 12, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let c = 2 * g.usize(1..4);
        let k = g.usize(2..5);
        let m = random_multiclass(g, f, c, k);
        let n = *g.pick(&BATCH_SIZES);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = multiclass_class_sums(&m, x);
                (sums.clone(), predict_argmax(&sums))
            })
            .collect();
        for (mode, compiler) in compilers(g, f) {
            let compiled = compiler.compile_multiclass(&m).unwrap();
            // Slots 0 and 1 of every class are dead by construction.
            assert!(
                compiled.stats.dead_all_exclude >= k && compiled.stats.dead_contradictory >= k,
                "f={f} c={c} k={k} mode {mode}: {:?}",
                compiled.stats
            );
            for (name, eval) in multiclass_matrix_compiled(&compiled) {
                assert_eq!(
                    eval(&rows),
                    want,
                    "f={f} c={c} k={k} n={n} mode {mode} engine {name}"
                );
            }
        }
    });
}

#[test]
fn compiled_cotm_matrix_is_bit_identical_on_boundary_widths() {
    prop("compiled engine matrix cotm", 12, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let c = g.usize(2..9);
        let k = g.usize(2..5);
        let m = random_cotm(g, f, c, k);
        let n = *g.pick(&BATCH_SIZES);
        let rows: Vec<Vec<bool>> = (0..n).map(|_| g.bools(f)).collect();
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = cotm_class_sums(&m, x);
                (sums.clone(), predict_argmax(&sums))
            })
            .collect();
        for (mode, compiler) in compilers(g, f) {
            let compiled = compiler.compile_cotm(&m).unwrap();
            assert!(
                compiled.stats.dead_all_exclude >= 1 && compiled.stats.dead_contradictory >= 1,
                "f={f} c={c} k={k} mode {mode}: {:?}",
                compiled.stats
            );
            for (name, eval) in cotm_matrix_compiled(&compiled) {
                assert_eq!(
                    eval(&rows),
                    want,
                    "f={f} c={c} k={k} n={n} mode {mode} engine {name}"
                );
            }
        }
    });
}

#[test]
fn all_dead_models_compile_and_serve_all_zero_sums() {
    // Adversarial compile input: a model whose every clause is dead
    // (alternating all-exclude and contradictory). The compiler must
    // not panic, the artifact validates with zero live clauses and
    // density 0.0, and every engine family serves all-zero sums.
    for &f in &BOUNDARY_WIDTHS {
        let p = TmParams { features: f, clauses: 4, classes: 3, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        for class in &mut m.clauses {
            for (j, clause) in class.iter_mut().enumerate() {
                clause.include = vec![j % 2 == 1; 2 * f];
            }
        }
        let mut cm = CoTmModel::zeroed(p);
        for (j, clause) in cm.clauses.iter_mut().enumerate() {
            clause.include = vec![j % 2 == 1; 2 * f];
        }
        for row in &mut cm.weights {
            row.fill(3);
        }
        let rows: Vec<Vec<bool>> = (0..65usize)
            .map(|s| (0..f).map(|i| (s + i) % 3 == 0).collect())
            .collect();
        for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
            let compiler =
                ModelCompiler::new(mode).with_synthetic_calibration(f, 8, 5);
            let compiled = compiler.compile_multiclass(&m).unwrap();
            assert!(compiled.validate().is_ok(), "f={f}");
            assert_eq!(compiled.stats.live_clauses, 0, "f={f}");
            assert_eq!(compiled.stats.density, 0.0, "f={f}");
            let want: Vec<BatchResult> = rows.iter().map(|_| (vec![0; 3], 0)).collect();
            for (name, eval) in multiclass_matrix_compiled(&compiled) {
                assert_eq!(eval(&rows), want, "f={f} mode {:?} engine {name}", mode);
            }
            let compiled = compiler.compile_cotm(&cm).unwrap();
            assert_eq!(compiled.stats.live_clauses, 0, "f={f}");
            let want: Vec<BatchResult> = rows.iter().map(|_| (vec![0; 3], 0)).collect();
            for (name, eval) in cotm_matrix_compiled(&compiled) {
                assert_eq!(eval(&rows), want, "f={f} mode {:?} engine {name}", mode);
            }
        }
    }
}

#[test]
fn duplicate_clauses_survive_compilation_exactly() {
    // Adversarial compile input: every clause in the model identical.
    // Deduplication is NOT part of the contract (duplicate clauses
    // carry independent votes), so the compiled engines must count the
    // duplicates exactly as the reference does — and full-mode
    // reordering (all fire counts tie) must fall back to the
    // deterministic source-id order.
    prop("duplicate clauses", 8, |g| {
        let f = *g.pick(&BOUNDARY_WIDTHS);
        let template: Vec<bool> = (0..2 * f).map(|_| g.chance(0.2)).collect();
        let p = TmParams { features: f, clauses: 6, classes: 3, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p.clone());
        for class in &mut m.clauses {
            for clause in class.iter_mut() {
                clause.include = template.clone();
            }
        }
        let mut cm = CoTmModel::zeroed(p.clone());
        for clause in cm.clauses.iter_mut() {
            clause.include = template.clone();
        }
        for row in &mut cm.weights {
            for w in row.iter_mut() {
                *w = g.i64(-(p.max_weight as i64)..p.max_weight as i64 + 1) as i32;
            }
        }
        let rows: Vec<Vec<bool>> = (0..65).map(|_| g.bools(f)).collect();
        let compiler = ModelCompiler::new(CompileMode::Full)
            .with_synthetic_calibration(f, 16, g.u64(0..u64::MAX));
        let compiled = compiler.compile_multiclass(&m).unwrap();
        // All duplicates tie on fire count: execution order falls back
        // to source ids, deterministically.
        for class in &compiled.classes {
            let srcs: Vec<u32> = class.iter().map(|cc| cc.source).collect();
            let mut sorted = srcs.clone();
            sorted.sort_unstable();
            assert_eq!(srcs, sorted, "tie-break must keep source order");
        }
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = multiclass_class_sums(&m, x);
                (sums.clone(), predict_argmax(&sums))
            })
            .collect();
        for (name, eval) in multiclass_matrix_compiled(&compiled) {
            assert_eq!(eval(&rows), want, "f={f} engine {name}");
        }
        let compiled = compiler.compile_cotm(&cm).unwrap();
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = cotm_class_sums(&cm, x);
                (sums.clone(), predict_argmax(&sums))
            })
            .collect();
        for (name, eval) in cotm_matrix_compiled(&compiled) {
            assert_eq!(eval(&rows), want, "f={f} engine {name}");
        }
    });
}

#[test]
fn minimum_shape_models_compile_exactly() {
    // Adversarial compile input: the smallest shapes the model
    // validator admits — one clause pair (multiclass), one shared
    // clause (CoTM), two classes. No slack for off-by-one id or
    // polarity decode bugs.
    for &f in &[1usize, 31, 64] {
        let p = TmParams { features: f, clauses: 2, classes: 2, ..TmParams::iris_paper() };
        let mut m = MultiClassTmModel::zeroed(p);
        for class in &mut m.clauses {
            // One live positive-polarity clause and one live negative.
            class[0].include = (0..2 * f).map(|l| l % 2 == 0).collect();
            class[1].include = (0..2 * f).map(|l| l % 2 == 1).collect();
        }
        let p1 = TmParams { features: f, clauses: 1, classes: 2, ..TmParams::iris_paper() };
        let mut cm = CoTmModel::zeroed(p1);
        cm.clauses[0].include = (0..2 * f).map(|l| l % 2 == 0).collect();
        cm.weights[0][0] = 3;
        cm.weights[1][0] = -2;
        let rows: Vec<Vec<bool>> = (0..16usize)
            .map(|s| (0..f).map(|i| (s >> (i % 4)) & 1 == 1).collect())
            .collect();
        for mode in [CompileMode::Off, CompileMode::Prune, CompileMode::Full] {
            let compiler = ModelCompiler::new(mode).with_synthetic_calibration(f, 8, 3);
            let compiled = compiler.compile_multiclass(&m).unwrap();
            let want: Vec<BatchResult> = rows
                .iter()
                .map(|x| {
                    let sums = multiclass_class_sums(&m, x);
                    (sums.clone(), predict_argmax(&sums))
                })
                .collect();
            for (name, eval) in multiclass_matrix_compiled(&compiled) {
                assert_eq!(eval(&rows), want, "f={f} mode {:?} engine {name}", mode);
            }
            let compiled = compiler.compile_cotm(&cm).unwrap();
            let want: Vec<BatchResult> = rows
                .iter()
                .map(|x| {
                    let sums = cotm_class_sums(&cm, x);
                    (sums.clone(), predict_argmax(&sums))
                })
                .collect();
            for (name, eval) in cotm_matrix_compiled(&compiled) {
                assert_eq!(eval(&rows), want, "f={f} mode {:?} engine {name}", mode);
            }
        }
    }
}

#[test]
fn reorder_is_output_invariant_under_random_calibration_batches() {
    // Full-mode reordering may permute the clause layout arbitrarily
    // (any calibration batch, any size), but the served sums never
    // move: an unrepresentative batch can only cost speed.
    prop("reorder output invariance", 10, |g| {
        let f = g.usize(4..40);
        let c = 2 * g.usize(1..5);
        let k = g.usize(2..4);
        let m = random_multiclass(g, f, c, k);
        let rows: Vec<Vec<bool>> = (0..30).map(|_| g.bools(f)).collect();
        let want: Vec<BatchResult> = rows
            .iter()
            .map(|x| {
                let sums = multiclass_class_sums(&m, x);
                (sums.clone(), predict_argmax(&sums))
            })
            .collect();
        let mut orders_seen = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let calib: Vec<Vec<bool>> =
                (0..g.usize(1..40)).map(|_| g.bools(f)).collect();
            let compiled = ModelCompiler::new(CompileMode::Full)
                .with_calibration(calib)
                .compile_multiclass(&m)
                .unwrap();
            orders_seen.insert(
                compiled
                    .classes
                    .iter()
                    .map(|class| class.iter().map(|cc| cc.source).collect::<Vec<_>>())
                    .collect::<Vec<_>>(),
            );
            for (name, eval) in multiclass_matrix_compiled(&compiled) {
                assert_eq!(eval(&rows), want, "f={f} c={c} k={k} engine {name}");
            }
        }
        // The batches were free to disagree on the order (usually they
        // do); the assertion above proved none of that reached the
        // outputs.
        assert!(!orders_seen.is_empty());
    });
}

/// The registry itself is part of the matrix: every backend the router
/// registers must round-trip through its public name (the wire/CLI
/// identity), names must be unique, and the registry must not silently
/// grow or shrink — lint rule R6 holds USAGE and selfcheck to this same
/// list, and iterating `Backend::ALL` here keeps the coverage
/// drift-proof as backends are added.
#[test]
fn registry_names_roundtrip_across_all_backends() {
    let mut seen = std::collections::BTreeSet::new();
    for b in Backend::ALL {
        let name = b.name();
        assert!(!name.is_empty());
        assert_eq!(Backend::parse(name), Some(b), "{name} must round-trip");
        assert!(seen.insert(name), "duplicate backend name {name}");
    }
    assert_eq!(Backend::ALL.len(), 16, "registry changed: update USAGE, selfcheck and this count");
    assert_eq!(Backend::parse("no-such-backend"), None);
}
