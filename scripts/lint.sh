#!/usr/bin/env bash
# Toolchain-less static-analysis tier (the first stage of verify.sh):
#
#   scripts/lint.sh [-- extra args for python3 -m analysis]
#
# Runs the python/analysis rule engine (rules r1-r7, see
# docs/INVARIANTS.md) over the Rust tree. Needs only python3 — no Rust
# toolchain, no pip packages — so it is the one machine check of the
# concurrency/panic-safety/parity contracts that runs on every CI
# image. Exit 0 means every rule is clean.
#
# To re-pin the r7 panic-path ratchet after a reviewed change:
#   scripts/lint.sh --update-ratchet
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v python3 >/dev/null 2>&1; then
    echo "lint.sh: python3 not found; the analysis tier cannot run." >&2
    exit 1
fi

PYTHONPATH="python${PYTHONPATH:+:$PYTHONPATH}" exec python3 -m analysis "$@"
