#!/usr/bin/env bash
# Native static/dynamic analysis for toolchain-equipped machines — the
# second enforcement layer behind scripts/lint.sh (which runs the same
# invariant catalog toolchain-lessly; see docs/INVARIANTS.md):
#
#   scripts/analysis.sh            # clippy -D warnings over all targets
#   RUN_TSAN=1 scripts/analysis.sh # additionally the ThreadSanitizer bar
#
# The TSan recipe is the concurrency bar for the direction-1 networked
# serving work: the coordinator suites (batcher, pool, server, shard,
# stats) under -Zsanitizer=thread. It needs a nightly toolchain with
# rust-src (cargo +nightly, -Zbuild-std), so it is opt-in via RUN_TSAN=1
# and documented here rather than wired into verify.sh.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "analysis.sh: cargo not found on PATH." >&2
    echo "This image is toolchain-less; the equivalent contracts are" >&2
    echo "enforced by scripts/lint.sh (python/analysis). Run this script" >&2
    echo "on a toolchain-equipped machine." >&2
    exit 1
fi

echo "== cargo clippy --all-targets -- -D warnings =="
# [lints.rust]/[lints.clippy] in Cargo.toml carry the per-lint levels;
# -D warnings promotes everything else that fires.
cargo clippy --all-targets -- -D warnings

echo "== cargo clippy --no-default-features (portable-only) =="
cargo clippy --all-targets --no-default-features -- -D warnings

if [ "${RUN_TSAN:-0}" = "1" ]; then
    echo "== ThreadSanitizer: coordinator suites =="
    # Nightly-only: TSan instruments std too, hence -Zbuild-std.
    RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
        --lib coordinator::
    RUSTFLAGS="-Zsanitizer=thread" \
        cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
        --test coordinator_props
else
    echo "(set RUN_TSAN=1 for the ThreadSanitizer pass — needs nightly + rust-src)"
fi

echo "analysis.sh: OK"
