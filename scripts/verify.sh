#!/usr/bin/env bash
# Tier-1 verify + bench compilation, as one command:
#
#   scripts/verify.sh
#
# Runs: cargo build --release && cargo test -q && cargo bench --no-run
# (benches are plain `harness = false` mains — `--no-run` proves they
# compile without paying their full runtime).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH." >&2
    echo "This image carries only the Python/JAX side of the stack; the" >&2
    echo "Rust tier-1 suite needs a Rust toolchain (rustup default stable)." >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo bench --no-run =="
cargo bench --no-run

echo "verify.sh: OK"
