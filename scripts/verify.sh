#!/usr/bin/env bash
# Tier-1 verify + bench compilation, as one command:
#
#   scripts/verify.sh
#
# Runs: the Python tier FIRST (JAX kernels, the consistent-hash-ring
# mirror, the inverted-index counter-sweep mirror, and the
# packed-trainer mirror with its same-seed bit-identity invariant — so
# toolchain-less images still validate the shard-routing, indexed-
# inference and packed-training algorithms), then cargo build --release
# && cargo test -q, the shard / coordinator / indexed / trainer
# conformance suites by name (so a routing, engine or trainer
# regression is visible at a glance), and cargo bench --no-run
# (benches are plain `harness = false` mains — `--no-run` proves they
# compile without paying their full runtime).
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v python3 >/dev/null 2>&1 && python3 -c "import pytest" >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    python3 -m pytest -q python/tests
else
    echo "verify.sh: pytest not found; skipping the Python tier." >&2
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH." >&2
    echo "This image carries only the Python/JAX side of the stack; the" >&2
    echo "Rust tier-1 suite needs a Rust toolchain (rustup default stable)." >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== shard / coordinator / indexed suites (named re-run for visibility) =="
cargo test -q --lib coordinator::
cargo test -q --lib tm::index
cargo test -q --test coordinator_props shard
cargo test -q --test equivalence sharded
cargo test -q --test equivalence indexed
cargo test -q --test bitparallel_equivalence indexed
cargo test -q --test bitparallel_equivalence auto

echo "== trainer suites (packed-evaluation bit-identity) =="
cargo test -q --lib tm::trainer_engine
cargo test -q --lib tm::train::
cargo test -q --lib tm::cotm_train
cargo test -q --test train_equivalence

echo "== cargo bench --no-run =="
cargo bench --no-run

echo "verify.sh: OK"
