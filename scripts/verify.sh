#!/usr/bin/env bash
# Tier-1 verify + bench compilation, as one command:
#
#   scripts/verify.sh [--python-only]
#
# Runs: the static-analysis lint tier FIRST (scripts/lint.sh — the
# toolchain-less enforcement of the invariant catalog in
# docs/INVARIANTS.md: lock discipline, panic containment, slot
# accounting, unsafe audit, golden-vector parity, registry coverage,
# the panic-path ratchet, the compile-pipeline shape, the async
# atomic-ordering discipline), then the Python
# tier (JAX kernels, the consistent-hash-ring
# mirror, the inverted-index counter-sweep mirror, the compressed
# include-list-walk mirror with its shared golden vectors, the
# packed-trainer mirror with its same-seed bit-identity invariant, the
# tiled bit-sliced batch-layout mirror, the model-compile-pass
# mirror with its prune/reorder/plan oracles, and the wire-protocol
# mirror (python/netproto.py: shared golden frames + adversarial
# decoding + socket-pair streaming), and the async clause-parallel
# trainer mirror (python/asynctrain.py: stream-seed + trained-model
# goldens, indexed==packed fuzz, and the statistical accuracy-parity
# bar) — so toolchain-less images
# still validate the shard-routing, indexed-inference,
# compressed-inference, packed-training, async-training, SIMD-tile,
# model-compile and
# network-framing algorithms), then
# cargo build --release && cargo test -q, the shard / coordinator /
# networked-serving / indexed / compressed / compile / engine-matrix /
# trainer / SIMD
# conformance suites by name (so a routing, engine, compile-pass,
# trainer, lane-dispatch or wire-protocol
# regression is visible at a glance), one portable-only build with the
# vector paths compiled out (--no-default-features: the portable
# reference must keep compiling and passing on its own), and cargo
# bench --no-run (benches are plain `harness = false` mains — `--no-run`
# proves they compile without paying their full runtime).
#
# --python-only exits 0 after the lint + Python tiers, so toolchain-less
# CI images report a clean pass instead of hard-failing on missing cargo.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHON_ONLY=0
for arg in "$@"; do
    case "$arg" in
        --python-only) PYTHON_ONLY=1 ;;
        *) echo "verify.sh: unknown argument $arg" >&2; exit 2 ;;
    esac
done

echo "== scripts/lint.sh (static-analysis tier) =="
scripts/lint.sh

if command -v python3 >/dev/null 2>&1 && python3 -c "import pytest" >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    python3 -m pytest -q python/tests
else
    echo "verify.sh: pytest not found; skipping the Python tier." >&2
fi

if [ "$PYTHON_ONLY" = "1" ]; then
    echo "verify.sh: OK (lint + Python tiers; --python-only skipped the Rust tiers)"
    exit 0
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify.sh: cargo not found on PATH." >&2
    echo "This image carries only the Python/JAX side of the stack; the" >&2
    echo "Rust tier-1 suite needs a Rust toolchain (rustup default stable)." >&2
    echo "(Use --python-only for a clean pass on toolchain-less images.)" >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== shard / coordinator / indexed / compressed suites (named re-run for visibility) =="
cargo test -q --lib coordinator::
cargo test -q --lib tm::index
cargo test -q --lib tm::compressed
cargo test -q --test coordinator_props shard
cargo test -q --test equivalence sharded
cargo test -q --test equivalence indexed
cargo test -q --test equivalence compressed
cargo test -q --test bitparallel_equivalence indexed
cargo test -q --test bitparallel_equivalence auto

echo "== networked serving tier (frame codec, messages, loopback conformance) =="
cargo test -q --lib coordinator::net
cargo test -q --test net_serving

echo "== model-compile pass (prune/reorder/plan exactness + artifact serde) =="
cargo test -q --lib tm::compile
cargo test -q --lib tm::serde

echo "== cross-engine differential conformance matrix (incl. compiled-artifact rows) =="
cargo test -q --test engine_matrix
cargo test -q --test engine_matrix compiled

echo "== trainer suites (packed-evaluation bit-identity) =="
cargo test -q --lib tm::trainer_engine
cargo test -q --lib tm::train::
cargo test -q --lib tm::cotm_train
cargo test -q --test train_equivalence

echo "== async clause-parallel trainer (concurrency invariants + accuracy parity) =="
cargo test -q --lib tm::async_train
cargo test -q --test train_equivalence async

echo "== SIMD lane suites (dispatch bit-identity across lane widths) =="
cargo test -q --lib tm::simd
cargo test -q --lib tm::bitpack
cargo test -q --test simd_dispatch

echo "== portable-only build (vector paths compiled out) =="
# The portable 4x-unrolled baseline is the bit-exact reference; it must
# compile and pass with the x86 vector kernels absent.
cargo build --release --no-default-features
cargo test -q --no-default-features --lib tm::simd
cargo test -q --no-default-features --test simd_dispatch

echo "== cargo bench --no-run =="
cargo bench --no-run

echo "verify.sh: OK"
