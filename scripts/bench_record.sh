#!/usr/bin/env bash
# Perf-trajectory recorder: run the serving-tier, training-tier and
# SIMD-lane benches and append their output as one JSON entry to a
# JSON-lines file (one object per recorded run), so successive PRs
# accumulate comparable numbers.
#
#   scripts/bench_record.sh [label] [out-file]
#
# The output file defaults to BENCH_PR10.json and can be overridden by
# the second positional argument or the BENCH_OUT environment variable
# (argument wins). Earlier PRs recorded to BENCH_PR3.json ..
# BENCH_PR9.json; those files stay as recorded history.
#
# Needs a Rust toolchain; the CI image carries none (see ROADMAP.md), so
# run this on a toolchain-equipped machine and commit the appended entry.
set -euo pipefail
cd "$(dirname "$0")/.."

LABEL="${1:-$(git rev-parse --short HEAD 2>/dev/null || echo unlabelled)}"
OUT="${2:-${BENCH_OUT:-BENCH_PR10.json}}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench_record.sh: cargo not found on PATH." >&2
    echo "The perf trajectory needs a toolchain-equipped machine; this" >&2
    echo "image carries only the Python/JAX tier." >&2
    exit 1
fi

echo "== cargo bench --bench compile_effect =="
COMPILE_OUT="$(cargo bench --bench compile_effect)"
echo "$COMPILE_OUT"

echo "== cargo bench --bench compressed_vs_all =="
COMPRESSED_OUT="$(cargo bench --bench compressed_vs_all)"
echo "$COMPRESSED_OUT"

echo "== cargo bench --bench indexed_vs_bitpar =="
INDEXED_OUT="$(cargo bench --bench indexed_vs_bitpar)"
echo "$INDEXED_OUT"

echo "== cargo bench --bench bitparallel_vs_ref =="
BITPAR_OUT="$(cargo bench --bench bitparallel_vs_ref)"
echo "$BITPAR_OUT"

echo "== cargo bench --bench train_packed_vs_ref =="
TRAIN_OUT="$(cargo bench --bench train_packed_vs_ref)"
echo "$TRAIN_OUT"

echo "== cargo bench --bench train_async_scaling =="
ASYNC_OUT="$(cargo bench --bench train_async_scaling)"
echo "$ASYNC_OUT"

echo "== cargo bench --bench simd_vs_scalar =="
SIMD_OUT="$(cargo bench --bench simd_vs_scalar)"
echo "$SIMD_OUT"

echo "== cargo bench --bench net_loopback =="
NET_OUT="$(cargo bench --bench net_loopback)"
echo "$NET_OUT"

# JSON-escape via python3 (present wherever the Python tier runs); fall
# back to a warning rather than writing malformed JSON by hand.
if ! command -v python3 >/dev/null 2>&1; then
    echo "bench_record.sh: python3 not found; cannot append $OUT." >&2
    exit 1
fi
LABEL="$LABEL" COMPILE_OUT="$COMPILE_OUT" COMPRESSED_OUT="$COMPRESSED_OUT" \
INDEXED_OUT="$INDEXED_OUT" BITPAR_OUT="$BITPAR_OUT" TRAIN_OUT="$TRAIN_OUT" \
ASYNC_OUT="$ASYNC_OUT" SIMD_OUT="$SIMD_OUT" NET_OUT="$NET_OUT" OUT="$OUT" \
python3 - <<'EOF'
import datetime
import json
import os

entry = {
    "label": os.environ["LABEL"],
    "recorded_utc": datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds"
    ),
    "compile_effect": os.environ["COMPILE_OUT"].splitlines(),
    "compressed_vs_all": os.environ["COMPRESSED_OUT"].splitlines(),
    "indexed_vs_bitpar": os.environ["INDEXED_OUT"].splitlines(),
    "bitparallel_vs_ref": os.environ["BITPAR_OUT"].splitlines(),
    "train_packed_vs_ref": os.environ["TRAIN_OUT"].splitlines(),
    "train_async_scaling": os.environ["ASYNC_OUT"].splitlines(),
    "simd_vs_scalar": os.environ["SIMD_OUT"].splitlines(),
    "net_loopback": os.environ["NET_OUT"].splitlines(),
}
path = os.environ["OUT"]
with open(path, "a", encoding="utf-8") as f:
    f.write(json.dumps(entry) + "\n")
print(f"bench_record.sh: appended entry {entry['label']!r} to {path}")
EOF
